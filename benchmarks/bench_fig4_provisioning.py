"""Paper Fig. 4: our load-balancing + Newton provisioning vs the static
StaRatio (1:6) and StaPSRatio (1:6:6) heuristics, over several throughput
limits (the figure's x-axis)."""

from __future__ import annotations

from benchmarks.common import emit, fmt_cost, timed
from repro.core import (
    SchedulingPlan, TrainingJob, build_stages, default_fleet, monetary_cost,
    paper_model_profiles,
)
from repro.core.provision import provision, provision_sta_ratio
from repro.core.schedulers import RLScheduler

FLEET = default_fleet()


def run() -> None:
    profs = paper_model_profiles("CTRDNN", FLEET)
    for limit in (100_000.0, 200_000.0, 400_000.0):
        job = TrainingJob(throughput_limit=limit)
        plan = RLScheduler(rounds=40, seed=0).schedule(profs, FLEET, job).plan
        stages = build_stages(plan, profs, FLEET)

        ours, us = timed(provision, stages, FLEET, job)
        c_ours = monetary_cost(plan, ours, profs, FLEET, job) if ours else float("inf")
        emit(f"fig4/ours/tp{limit:.0f}", us, f"cost={fmt_cost(c_ours)}")
        for name, with_ps in (("StaRatio", False), ("StaPSRatio", True)):
            sta, us = timed(provision_sta_ratio, stages, FLEET, job,
                            with_ps=with_ps)
            c = (monetary_cost(plan, sta, profs, FLEET, job)
                 if sta else float("inf"))
            rel = f";vs_ours={c / c_ours:.3f}" if c_ours and c == c else ""
            emit(f"fig4/{name}/tp{limit:.0f}", us, f"cost={fmt_cost(c)}{rel}")
