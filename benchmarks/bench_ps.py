"""Sharded-PS benchmark: pull/push throughput + async overlap speedup.

Three measurements:

* **pull/push throughput** across shard counts — rows/s and GB/s of the
  routed gather / COO scatter-add paths;
* **overlap**: steady-state step throughput of the async double-buffered
  ``PSClient`` vs the synchronous pull→compute→push baseline on the
  reduced CTR workload (acceptance: ≥1.3×).  The headline measurement
  models the worker↔PS network hop with a per-op RPC latency calibrated
  to the compute time (``--comm-ratio``): in the paper's deployment
  workers and PS are separate hosts and the hop rides the network/NIC,
  not worker CPU, so the client can genuinely hide it — whereas on this
  single-process container every phase is CPU-bound and software-only
  overlap is bounded by the core count (a 2-core box shows ~1.0–1.1×;
  that pass is still reported, as ``*_sw``, for reference);
* the measured traffic fed back through the **cost-model bridge**
  (``PSTelemetry.to_resource`` / ``embedding_odt``).

  PYTHONPATH=src python benchmarks/bench_ps.py [--smoke]
  PYTHONPATH=src python -m benchmarks.run --only ps
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

try:
    from benchmarks.common import emit, sync, write_artifact
except ImportError:   # direct `python benchmarks/bench_ps.py` run
    from common import emit, sync, write_artifact

from repro import obs
from repro.obs import trace as obs_trace
from repro.ps import CTRConfig, ShardedTable, make_step_fn, make_table, train_ctr_ps
from repro.ps.workload import train_ctr_elastic

#: steady-state window: drop the leading fraction (jit compile, cold
#: queues, first tier re-pin) before measuring step rate
WARM_FRACTION = 0.25


def _steady_steps_per_sec(summary: dict) -> float:
    ts = summary["step_ts"]
    w = max(1, int(len(ts) * WARM_FRACTION))
    return (len(ts) - 1 - w) / (ts[-1] - ts[w])


def bench_pull_push(*, vocab: int, dim: int, n_ids: int, iters: int) -> None:
    rng = np.random.default_rng(0)
    ids = (rng.pareto(1.2, (n_ids,)) * 1000).astype(np.int64) % vocab
    ids = ids.astype(np.int32)
    grads = rng.standard_normal((n_ids, dim)).astype(np.float32)
    for shards in (1, 2, 4, 8):
        table = ShardedTable(vocab, dim, shards, jax.random.PRNGKey(0))
        sync(table.pull(ids))                # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            out = table.pull(ids)
        sync(out)                            # fence queued device work
        dt = (time.perf_counter() - t0) / iters
        gb = n_ids * dim * 4 / 1e9
        emit(f"ps_pull_s{shards}", dt * 1e6,
             f"{n_ids / dt / 1e6:.1f}Mrows/s {gb / dt:.2f}GB/s")

        table.push(ids, grads, lr=0.01)      # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            table.push(ids, grads, lr=0.01)
        sync(table.pull(ids[:1]))            # fence the last shard apply
        dt = (time.perf_counter() - t0) / iters
        emit(f"ps_push_s{shards}", dt * 1e6,
             f"{n_ids / dt / 1e6:.1f}Mrows/s {gb / dt:.2f}GB/s")


def _measure_compute(cfg: CTRConfig) -> float:
    """Median wall time of the jitted CTR step alone (no PS traffic)."""
    import jax.numpy as jnp

    from repro.ps import click_stream, init_tower

    step_fn = make_step_fn(cfg)
    tower = init_tower(cfg, jax.random.PRNGKey(1))
    b = next(click_stream(cfg))
    table = make_table(cfg, 1, with_monitor=False)
    rows = table.pull(b["ids"])
    labels = jnp.asarray(b["label"])
    jax.block_until_ready(step_fn(tower, rows, labels))   # compile
    samples = []
    for _ in range(10):
        t0 = time.perf_counter()
        jax.block_until_ready(step_fn(tower, rows, labels))
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples))


def bench_overlap(*, cfg: CTRConfig, steps: int, shards: int,
                  rpc_latency_s: float, tag: str) -> float:
    repin = max(10, steps // 5)   # exercise tier re-pinning a few times
    sync = train_ctr_ps(cfg, steps=steps, num_shards=shards, mode="sync",
                        rpc_latency_s=rpc_latency_s, repin_interval=repin)
    async_ = train_ctr_ps(cfg, steps=steps, num_shards=shards, mode="async",
                          rpc_latency_s=rpc_latency_s, repin_interval=repin)
    s_rate = _steady_steps_per_sec(sync)
    a_rate = _steady_steps_per_sec(async_)
    emit(f"ps_sync_step{tag}", 1e6 / s_rate, f"{s_rate:.1f}steps/s")
    emit(f"ps_async_step{tag}", 1e6 / a_rate, f"{a_rate:.1f}steps/s")
    speedup = a_rate / s_rate
    emit(f"ps_overlap_speedup{tag}", 0.0,
         f"{speedup:.2f}x async-vs-sync (target >=1.3x)")
    # cost-model bridge: re-anchor the CPU resource type's bandwidth terms
    # to the measured PS traffic of the async run — sanity print only
    tel_summary = (f"pull {async_['pull_bw_gbs']:.2f}GB/s "
                   f"push {async_['push_bw_gbs']:.2f}GB/s "
                   f"hot {async_['hot_pull_fraction']:.0%}")
    emit(f"ps_telemetry{tag}", 0.0, tel_summary)
    emit(f"ps_cost_bridge{tag}", async_["embedding_odt_sync"] * 1e6,
         f"ingest_bw={async_['measured_ingest_bw'] / 1e9:.2f}GB/s "
         f"net_bw={async_['measured_net_bw'] / 1e9:.2f}GB/s "
         f"odt_act={async_['embedding_odt_act'] * 1e6:.0f}us/B_o")
    return speedup


def _post_event_rate(summary: dict, event_step: int) -> float:
    """Steady step rate over the window after ``event_step`` (plus a 10%
    settle margin) — the post-join / post-recovery regime."""
    ts = summary["step_ts"]
    w = min(len(ts) - 2, event_step + max(1, int(len(ts) * 0.1)))
    return (len(ts) - 1 - w) / max(ts[-1] - ts[w], 1e-9)


def bench_elastic(*, cfg: CTRConfig, steps: int, shards: int,
                  tag: str) -> float:
    """Elastic fleet scenarios: join mid-run and kill+recover mid-run,
    gated on steady-state throughput parity (≥0.9×) vs the same fleet
    left static, with migration/recovery times emitted.  Returns the
    worst parity ratio."""
    event_step = steps // 3
    common = dict(steps=steps, num_shards=shards, optimizer="sgd",
                  mode="sync")
    static = train_ctr_elastic(cfg, **common)
    base_rate = _post_event_rate(static, event_step)
    emit(f"ps_elastic_static_step{tag}", 1e6 / base_rate,
         f"{base_rate:.1f}steps/s")

    join = train_ctr_elastic(cfg, **common,
                             events=[(event_step, "join", None)])
    join_rate = _post_event_rate(join, event_step)
    join_parity = join_rate / base_rate
    emit(f"ps_elastic_join_time{tag}", join["join_seconds"] * 1e6,
         f"live slab migration to the joining shard")
    emit(f"ps_elastic_join_parity{tag}", 1e6 / join_rate,
         f"{join_parity:.2f}x of static (target >=0.9x)")

    kill = train_ctr_elastic(cfg, **common,
                             events=[(event_step, "kill", 0)])
    kill_rate = _post_event_rate(kill, event_step)
    kill_parity = kill_rate / base_rate
    emit(f"ps_elastic_recovery_time{tag}", kill["recovery_seconds"] * 1e6,
         f"replica promotion + re-replication after shard kill")
    emit(f"ps_elastic_kill_parity{tag}", 1e6 / kill_rate,
         f"{kill_parity:.2f}x of static (target >=0.9x)")
    # sync replication + deterministic PS optimizer: the interrupted run's
    # loss trajectory must match the static run's exactly
    drift = max(abs(a - b) for a, b in zip(static["losses"],
                                           kill["losses"]))
    emit(f"ps_elastic_lossless{tag}", drift * 1e6,
         f"max |loss drift| vs uninterrupted run = {drift:.2e}")
    if drift > 1e-6:
        raise RuntimeError(
            f"kill-recovery loss trajectory drifted by {drift:.3e} "
            f"from the uninterrupted run")
    return min(join_parity, kill_parity)


def bench_obs_overhead(*, cfg: CTRConfig, steps: int, shards: int) -> None:
    """The observability tax, two ways:

    * **disabled**: ns per ``span()`` call with the obs switch off (one
      branch + a shared no-op object), scaled to spans-per-step against
      the measured step time — the ≤1% claim, shown analytically because
      a sub-0.1% effect is unmeasurable in 50-step wall times;
    * **enabled**: steady-state CTR step rate with full instrumentation
      (client + shard spans, registry counters) vs disabled, gated at
      ≤5% overhead.  Best of 3 attempts — the quantity is a property of
      the code, so scheduler noise only ever *inflates* an attempt.
    """
    # disabled-span microbench
    n = 200_000
    obs.configure(enabled=False)   # a known baseline, whatever the env
    t0 = time.perf_counter()
    for _ in range(n):
        with obs_trace.span("bench.noop", "bench"):
            pass
    ns_off = (time.perf_counter() - t0) / n * 1e9
    step_s = _measure_compute(cfg)
    # per step: 1 client pull + 1 push_apply + (pull+add) per shard
    spans_per_step = 2 + 2 * shards
    frac = ns_off * 1e-9 * spans_per_step / step_s
    emit("ps_obs_span_disabled", ns_off / 1e3,
         f"{ns_off:.0f}ns/span; {spans_per_step}spans/step = "
         f"{frac:.4%} of a {step_s * 1e3:.1f}ms step (target <=1%)")
    if frac > 0.01:
        raise RuntimeError(
            f"disabled-obs span overhead {frac:.2%} of step time "
            f"exceeds the 1% budget")

    # enabled-vs-disabled steady state
    overhead = float("inf")
    for _ in range(3):
        off = train_ctr_ps(cfg, steps=steps, num_shards=shards, mode="sync",
                           repin_interval=10 * steps)
        obs.configure(enabled=True)
        try:
            on = train_ctr_ps(cfg, steps=steps, num_shards=shards,
                              mode="sync", repin_interval=10 * steps)
        finally:
            obs.configure(enabled=False)
        ratio = _steady_steps_per_sec(off) / _steady_steps_per_sec(on)
        overhead = min(overhead, max(0.0, ratio - 1.0))
        if overhead <= 0.05:
            break
    emit("ps_obs_overhead_enabled", 0.0,
         f"{overhead:.1%} enabled-vs-disabled steady-state (target <=5%)")
    if overhead > 0.05:
        raise RuntimeError(
            f"enabled-obs steady-state overhead {overhead:.1%} exceeds "
            f"the 5% budget")


def bench_chaos_machinery(*, cfg: CTRConfig, steps: int,
                          shards: int) -> None:
    """The fault-tolerance tax with faults disabled: per-request retry
    bookkeeping + seq-dedup + heartbeat plumbing + periodic unified
    checkpoints must cost ≤2% steady-state CTR throughput vs the same
    trainer with the machinery stripped to its minimum (single-attempt
    retry policy, no checkpoints).  Best of 3 — the quantity is a
    property of the code, so scheduler noise only inflates an attempt."""
    import tempfile

    from repro.ps.transport import InProcTransport, RetryPolicy

    common = dict(steps=steps, num_shards=shards, optimizer="sgd",
                  mode="sync")
    every = max(10, steps // 5)
    overhead = float("inf")
    for _ in range(3):
        bare = train_ctr_elastic(
            cfg, **common,
            transport=InProcTransport(retry=RetryPolicy(max_attempts=1)))
        with tempfile.TemporaryDirectory(prefix="bench-ps-ckpt-") as d:
            armed = train_ctr_elastic(cfg, **common, ckpt_dir=d,
                                      ckpt_every=every)
        ratio = _steady_steps_per_sec(bare) / _steady_steps_per_sec(armed)
        overhead = min(overhead, max(0.0, ratio - 1.0))
        if overhead <= 0.02:
            break
    emit("ps_chaos_machinery_overhead", 0.0,
         f"{overhead:.1%} retry+heartbeat+ckpt(every {every}) vs stripped "
         f"steady-state (target <=2%)")
    if overhead > 0.02:
        raise RuntimeError(
            f"fault-tolerance machinery costs {overhead:.1%} steady-state "
            f"throughput with faults disabled, above the 2% budget")


def run(smoke: bool = False, comm_ratio: float = 2.0) -> None:
    if smoke:
        # keep the full-size model (its compute:push balance is what makes
        # overlap visible) but a smaller vocab and fewer steps
        tp = dict(vocab=50_000, dim=16, n_ids=4096, iters=5)
        cfg = CTRConfig(vocab=50_000)
        steps = 50
    else:
        tp = dict(vocab=500_000, dim=32, n_ids=8192, iters=20)
        cfg = CTRConfig()
        steps = 300
    bench_pull_push(**tp)

    shards = 4
    # pure software overlap (no simulated network): bounded by spare cores,
    # reported for reference only
    bench_overlap(cfg=cfg, steps=steps, shards=shards,
                  rpc_latency_s=0.0, tag="_sw")
    # headline: simulated PS network hop, per-op RPC latency calibrated so
    # that total comm time ≈ comm_ratio × compute time (the balanced
    # regime HeterPS provisions for); the async client must hide it.
    # One retry with a fresh calibration absorbs transient machine noise
    # (steady-state windows are ~40 steps on a shared box).
    speedup = 0.0
    for attempt, tag in enumerate(("", "_retry")):
        compute = _measure_compute(cfg)
        rpc = comm_ratio * compute / 2.0
        emit(f"ps_compute_calibration{tag}", compute * 1e6,
             f"rpc_latency={rpc * 1e3:.2f}ms/op")
        speedup = bench_overlap(cfg=cfg, steps=steps, shards=shards,
                                rpc_latency_s=rpc, tag=tag)
        if speedup >= 1.3:
            break
    if speedup < 1.3:
        # plain Exception so benchmarks/run.py's per-suite failure
        # accounting catches it; still exits nonzero under direct runs
        raise RuntimeError(
            f"async overlap speedup {speedup:.2f}x below the 1.3x target")

    # elastic fleet: join + kill/recovery mid-training, parity-gated
    # against the static fleet (one retry absorbs shared-box noise)
    elastic_steps = max(30, steps // 2)
    parity = 0.0
    for tag in ("_elastic", "_elastic_retry"):
        parity = bench_elastic(cfg=cfg, steps=elastic_steps, shards=3,
                               tag=tag)
        if parity >= 0.9:
            break
    if parity < 0.9:
        raise RuntimeError(
            f"elastic fleet steady-state throughput {parity:.2f}x of the "
            f"static fleet, below the 0.9x target")

    # observability tax: disabled must be free, enabled must stay <=5%
    bench_obs_overhead(cfg=cfg, steps=min(steps, 100), shards=shards)

    # fault-tolerance tax: the chaos machinery must be ~free when calm
    bench_chaos_machinery(cfg=cfg, steps=min(steps, 100), shards=3)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI (<1 min)")
    ap.add_argument("--comm-ratio", type=float, default=2.0,
                    help="simulated PS comm:compute time ratio (sparse CTR "
                         "models are communication-dominated — §3)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    t0 = time.time()
    try:
        run(smoke=args.smoke, comm_ratio=args.comm_ratio)
    except BaseException as e:
        write_artifact("ps", ok=False, error=repr(e),
                       seconds=time.time() - t0)
        raise
    write_artifact("ps", ok=True, seconds=time.time() - t0)


if __name__ == "__main__":
    main()
