"""Paper Table 2: Brute Force vs RL — scheduling time and plan quality as
the layer count grows (CTRDNN variants: 8/12/16 layers) and with more
resource types (BF(2) vs BF(4)).  BF time explodes exponentially; RL stays
flat and matches the BF optimum."""

from __future__ import annotations

import math

from benchmarks.common import emit, fmt_cost
from repro.core import TrainingJob, default_fleet, make_fleet
from repro.core.profiles import ctrdnn_variant, profile_layers
from repro.core.schedulers import BruteForceScheduler, RLScheduler

JOB = TrainingJob()


def run() -> None:
    for T, layer_counts in ((2, (8, 12, 16)), (4, (8,))):
        fleet = default_fleet() if T == 2 else make_fleet(T)
        for L in layer_counts:
            profs = profile_layers(ctrdnn_variant(L), fleet)
            bf = BruteForceScheduler(max_evals=300_000).schedule(profs, fleet, JOB)
            rl = RLScheduler(rounds=60, seed=0).schedule(profs, fleet, JOB)
            match = (
                "match" if rl.cost <= bf.cost * 1.02 else
                f"gap={rl.cost / bf.cost:.3f}"
            )
            emit(f"table2/BF({T})/L{L}", bf.wall_time_s * 1e6,
                 f"cost={fmt_cost(bf.cost)};evals={bf.evaluations}")
            emit(f"table2/RL({T})/L{L}", rl.wall_time_s * 1e6,
                 f"cost={fmt_cost(rl.cost)};{match}")
        # estimated BF time for the next sizes (paper marks these "E")
        if T == 4:
            per_eval_us = bf.wall_time_s * 1e6 / bf.evaluations
            for L in (12, 16):
                emit(f"table2/BF({T})/L{L}(E)", per_eval_us * (T**L),
                     "estimated")
