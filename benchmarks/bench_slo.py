"""SLO load harness: trace-driven open-loop load on the serve path.

Generates a deterministic workload — Zipfian prompt/output lengths
(quantized to a few buckets, since exact-length prefill compiles once per
distinct prompt length) with bursty Poisson arrivals (a two-state
Markov-modulated process: quiet ↔ burst) — and drives
:func:`repro.launch.serve.serve_continuous` open-loop through its
``arrival_s`` seam.  Reports:

* **TTFT** p50/p99 (arrival → first output token; first request per
  length bucket pays jit compile, which is the realistic cold-start tail)
  and **TPOT** p50/p99 (decode seconds per output token), read from the
  ``serve.ttft_s`` / ``serve.tpot_s`` obs histograms and cross-checked
  against the exact per-request lists ``serve_continuous`` returns
  (agreement within the histogram's ``GROWTH`` error bound — the same
  invariant tests/test_obs.py pins);
* **goodput**: output tokens of SLO-met requests per wall second, with
  generous absolute SLOs (CLI-settable) so the smoke gate — goodput > 0
  with every request completed — is noise-immune on a shared box;
* the live cost-model bridge (``obs.snapshot_resources``) fed by the
  run's serve signals.

  PYTHONPATH=src python benchmarks/bench_slo.py [--smoke] [--obs-dir D]
  PYTHONPATH=src python -m benchmarks.run --only slo
"""

from __future__ import annotations

import argparse
import math
import random
import time

try:
    from benchmarks.common import emit, write_artifact
except ImportError:  # run directly: python benchmarks/bench_slo.py
    from common import emit, write_artifact

from repro import obs
from repro.core.resources import CPU_CORE
from repro.launch.serve import serve_continuous
from repro.obs.metrics import GROWTH

#: length buckets (few distinct values bound prefill recompiles); Zipf
#: weight 1/rank^ZIPF_A makes the short bucket dominate, like real traffic
PROMPT_BUCKETS = (8, 16, 32)
GEN_BUCKETS = (4, 8, 16)
ZIPF_A = 1.2


def make_workload(n: int, *, seed: int = 0, mean_interarrival_s: float = 0.08,
                  burst_factor: float = 4.0, p_flip: float = 0.25,
                  ) -> tuple[list[tuple[int, int]], list[float]]:
    """Deterministic (requests, arrival_s): Zipfian bucketed lengths,
    bursty Poisson arrivals (burst periods run ``burst_factor``× the
    quiet arrival rate; state flips with prob ``p_flip`` per arrival)."""
    rng = random.Random(seed)
    w = [1.0 / (k + 1) ** ZIPF_A for k in range(len(PROMPT_BUCKETS))]
    reqs = [(rng.choices(PROMPT_BUCKETS, w)[0],
             rng.choices(GEN_BUCKETS, w)[0]) for _ in range(n)]
    t, burst, arrivals = 0.0, False, []
    for _ in range(n):
        rate = (burst_factor if burst else 1.0) / mean_interarrival_s
        t += rng.expovariate(rate)
        arrivals.append(t)
        if rng.random() < p_flip:
            burst = not burst
    return reqs, arrivals


def _check_quantiles(name: str, hist, values: list[float]) -> None:
    """The histogram's bounded-relative-error contract against the exact
    sample: each reported quantile within a factor GROWTH of the true
    order statistic (same rank convention as Histogram.quantile)."""
    vs = sorted(values)
    for q in (0.5, 0.99):
        est = hist.quantile(q)
        rank = min(len(vs) - 1, max(0, math.ceil(q * len(vs)) - 1))
        true = vs[rank]
        lo, hi = true / GROWTH - 1e-12, true * GROWTH + 1e-12
        if not (lo <= est <= hi):
            raise RuntimeError(
                f"{name} p{int(q * 100)}: histogram {est:.6g} vs exact "
                f"{true:.6g} outside the {GROWTH:.3f}x bound")


def run(smoke: bool = False, *, n_requests: int | None = None, seed: int = 0,
        ttft_slo_s: float = 30.0, tpot_slo_s: float = 1.0) -> dict:
    n = n_requests if n_requests is not None else (10 if smoke else 32)
    reqs, arrivals = make_workload(n, seed=seed)
    # the histograms are the cross-check target — the run needs obs on,
    # and a clean registry so prior suites' serve metrics don't bleed in
    was_enabled = obs.enabled()
    obs.configure(enabled=True)
    obs.REGISTRY.reset()
    try:
        out = serve_continuous(
            "llama3.2-1b", slots=4, page_size=8, decode_chunk=4,
            requests=reqs, arrival_s=arrivals,
            max_seq_len=max(PROMPT_BUCKETS) + max(GEN_BUCKETS) + 4)
    finally:
        obs.configure(enabled=was_enabled)

    ttft, tpot = out["ttft_s"], out["tpot_s"]
    assert all(v is not None for v in ttft + tpot), "request never finished"
    (_, h_ttft), = obs.REGISTRY.find("serve.ttft_s")
    (_, h_tpot), = obs.REGISTRY.find("serve.tpot_s")
    _check_quantiles("ttft", h_ttft, ttft)
    _check_quantiles("tpot", h_tpot, tpot)

    wall = out["wall_s"]
    met = [i for i in range(n)
           if ttft[i] <= ttft_slo_s and tpot[i] <= tpot_slo_s]
    good_tokens = sum(reqs[i][1] for i in met)
    goodput = good_tokens / max(wall, 1e-9)

    emit("slo_ttft_p50", h_ttft.quantile(0.5) * 1e6,
         f"p99={h_ttft.quantile(0.99):.3f}s (exact-list cross-check ok)")
    emit("slo_tpot_p50", h_tpot.quantile(0.5) * 1e6,
         f"p99={h_tpot.quantile(0.99):.3f}s")
    emit("slo_goodput", 0.0,
         f"{goodput:.1f}tok/s good ({len(met)}/{n} requests met "
         f"ttft<={ttft_slo_s:.0f}s tpot<={tpot_slo_s:.1f}s; "
         f"wall={wall:.1f}s burst-Poisson arrivals over "
         f"{arrivals[-1]:.1f}s)")

    # live cost-model bridge: the serve signals land in the exact
    # ResourceType/LayerProfile shapes the scheduler consumes
    snap = obs.snapshot_resources(CPU_CORE)
    serve_sig = snap["serve"]
    emit("slo_bridge", 0.0,
         f"resource={snap['resource'].name} "
         f"ttft_p99={serve_sig['ttft']['p99']:.3f}s "
         f"pool={serve_sig['pool_pages_used']:.0f}/"
         f"{serve_sig['pool_pages_total']:.0f}pages "
         f"evictions={serve_sig['evictions']:.0f}")

    completed = all(len_ == g for len_, (_, g) in zip(out["generated"], reqs))
    if smoke:
        if not completed:
            raise RuntimeError(f"incomplete generations: {out['generated']}")
        if goodput <= 0.0:
            raise RuntimeError(f"goodput {goodput} not > 0")
        print(f"# slo gate ok: goodput={goodput:.1f}tok/s, "
              f"{len(met)}/{n} requests in SLO, quantiles within "
              f"{GROWTH:.3f}x of exact")
    return {"goodput_tok_s": goodput, "met": len(met), "n": n,
            "wall_s": wall,
            "ttft_p50_s": h_ttft.quantile(0.5),
            "ttft_p99_s": h_ttft.quantile(0.99),
            "tpot_p50_s": h_tpot.quantile(0.5),
            "tpot_p99_s": h_tpot.quantile(0.99)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: small workload + goodput/quantile gates")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ttft-slo", type=float, default=30.0,
                    help="TTFT SLO seconds (generous: first request per "
                         "length bucket pays jit compile)")
    ap.add_argument("--tpot-slo", type=float, default=1.0,
                    help="per-output-token SLO seconds")
    ap.add_argument("--obs-dir", default=None,
                    help="also write trace.json + metrics.jsonl here")
    args = ap.parse_args()
    if args.obs_dir:
        obs.configure(run_dir=args.obs_dir)
    print("name,us_per_call,derived")
    t0 = time.time()
    try:
        summary = run(smoke=args.smoke, n_requests=args.requests,
                      seed=args.seed, ttft_slo_s=args.ttft_slo,
                      tpot_slo_s=args.tpot_slo)
    except BaseException as e:
        write_artifact("slo", ok=False, error=repr(e),
                       seconds=time.time() - t0)
        raise
    if args.obs_dir:
        summary["obs"] = obs.flush()
    write_artifact("slo", ok=True, seconds=time.time() - t0, extra=summary)


if __name__ == "__main__":
    main()
