"""SLO load harness: trace-driven open-loop load on the serve path.

Generates a deterministic workload — Zipfian prompt/output lengths
(quantized to a few buckets, since exact-length prefill compiles once per
distinct prompt length) with bursty Poisson arrivals (a two-state
Markov-modulated process: quiet ↔ burst) — and drives
:func:`repro.launch.serve.serve_continuous` open-loop through its
``arrival_s`` seam.  Reports:

* **TTFT** p50/p99 (arrival → first output token; first request per
  length bucket pays jit compile, which is the realistic cold-start tail)
  and **TPOT** p50/p99 (decode seconds per output token), read from the
  ``serve.ttft_s`` / ``serve.tpot_s`` obs histograms and cross-checked
  against the exact per-request lists ``serve_continuous`` returns
  (agreement within the histogram's ``GROWTH`` error bound — the same
  invariant tests/test_obs.py pins);
* **goodput**: output tokens of SLO-met requests per wall second, with
  generous absolute SLOs (CLI-settable) so the smoke gate — goodput > 0
  with every request completed — is noise-immune on a shared box;
* the live cost-model bridge (``obs.snapshot_resources``) fed by the
  run's serve signals.

  PYTHONPATH=src python benchmarks/bench_slo.py [--smoke] [--obs-dir D]
  PYTHONPATH=src python -m benchmarks.run --only slo
"""

from __future__ import annotations

import argparse
import math
import random
import time

try:
    from benchmarks.common import emit, write_artifact
except ImportError:  # run directly: python benchmarks/bench_slo.py
    from common import emit, write_artifact

from repro import obs
from repro.core.resources import CPU_CORE
from repro.launch.serve import serve_continuous
from repro.obs.metrics import GROWTH

#: length buckets (few distinct values bound prefill recompiles); Zipf
#: weight 1/rank^ZIPF_A makes the short bucket dominate, like real traffic
PROMPT_BUCKETS = (8, 16, 32)
GEN_BUCKETS = (4, 8, 16)
ZIPF_A = 1.2


def make_workload(n: int, *, seed: int = 0, mean_interarrival_s: float = 0.08,
                  burst_factor: float = 4.0, p_flip: float = 0.25,
                  ) -> tuple[list[tuple[int, int]], list[float]]:
    """Deterministic (requests, arrival_s): Zipfian bucketed lengths,
    bursty Poisson arrivals (burst periods run ``burst_factor``× the
    quiet arrival rate; state flips with prob ``p_flip`` per arrival)."""
    rng = random.Random(seed)
    w = [1.0 / (k + 1) ** ZIPF_A for k in range(len(PROMPT_BUCKETS))]
    reqs = [(rng.choices(PROMPT_BUCKETS, w)[0],
             rng.choices(GEN_BUCKETS, w)[0]) for _ in range(n)]
    t, burst, arrivals = 0.0, False, []
    for _ in range(n):
        rate = (burst_factor if burst else 1.0) / mean_interarrival_s
        t += rng.expovariate(rate)
        arrivals.append(t)
        if rng.random() < p_flip:
            burst = not burst
    return reqs, arrivals


def _check_quantiles(name: str, hist, values: list[float]) -> None:
    """The histogram's bounded-relative-error contract against the exact
    sample: each reported quantile within a factor GROWTH of the true
    order statistic (same rank convention as Histogram.quantile)."""
    vs = sorted(values)
    for q in (0.5, 0.99):
        est = hist.quantile(q)
        rank = min(len(vs) - 1, max(0, math.ceil(q * len(vs)) - 1))
        true = vs[rank]
        lo, hi = true / GROWTH - 1e-12, true * GROWTH + 1e-12
        if not (lo <= est <= hi):
            raise RuntimeError(
                f"{name} p{int(q * 100)}: histogram {est:.6g} vs exact "
                f"{true:.6g} outside the {GROWTH:.3f}x bound")


def run(smoke: bool = False, *, n_requests: int | None = None, seed: int = 0,
        ttft_slo_s: float = 30.0, tpot_slo_s: float = 1.0) -> dict:
    n = n_requests if n_requests is not None else (10 if smoke else 32)
    reqs, arrivals = make_workload(n, seed=seed)
    # the histograms are the cross-check target — the run needs obs on,
    # and a clean registry so prior suites' serve metrics don't bleed in
    was_enabled = obs.enabled()
    obs.configure(enabled=True)
    obs.REGISTRY.reset()
    try:
        out = serve_continuous(
            "llama3.2-1b", slots=4, page_size=8, decode_chunk=4,
            requests=reqs, arrival_s=arrivals,
            max_seq_len=max(PROMPT_BUCKETS) + max(GEN_BUCKETS) + 4)
    finally:
        obs.configure(enabled=was_enabled)

    ttft, tpot = out["ttft_s"], out["tpot_s"]
    assert all(v is not None for v in ttft + tpot), "request never finished"
    (_, h_ttft), = obs.REGISTRY.find("serve.ttft_s")
    (_, h_tpot), = obs.REGISTRY.find("serve.tpot_s")
    _check_quantiles("ttft", h_ttft, ttft)
    _check_quantiles("tpot", h_tpot, tpot)

    wall = out["wall_s"]
    met = [i for i in range(n)
           if ttft[i] <= ttft_slo_s and tpot[i] <= tpot_slo_s]
    good_tokens = sum(reqs[i][1] for i in met)
    goodput = good_tokens / max(wall, 1e-9)

    emit("slo_ttft_p50", h_ttft.quantile(0.5) * 1e6,
         f"p99={h_ttft.quantile(0.99):.3f}s (exact-list cross-check ok)")
    emit("slo_tpot_p50", h_tpot.quantile(0.5) * 1e6,
         f"p99={h_tpot.quantile(0.99):.3f}s")
    emit("slo_goodput", 0.0,
         f"{goodput:.1f}tok/s good ({len(met)}/{n} requests met "
         f"ttft<={ttft_slo_s:.0f}s tpot<={tpot_slo_s:.1f}s; "
         f"wall={wall:.1f}s burst-Poisson arrivals over "
         f"{arrivals[-1]:.1f}s)")

    # live cost-model bridge: the serve signals land in the exact
    # ResourceType/LayerProfile shapes the scheduler consumes
    snap = obs.snapshot_resources(CPU_CORE)
    serve_sig = snap["serve"]
    emit("slo_bridge", 0.0,
         f"resource={snap['resource'].name} "
         f"ttft_p99={serve_sig['ttft']['p99']:.3f}s "
         f"pool={serve_sig['pool_pages_used']:.0f}/"
         f"{serve_sig['pool_pages_total']:.0f}pages "
         f"evictions={serve_sig['evictions']:.0f}")

    completed = all(len_ == g for len_, (_, g) in zip(out["generated"], reqs))
    if smoke:
        if not completed:
            raise RuntimeError(f"incomplete generations: {out['generated']}")
        if goodput <= 0.0:
            raise RuntimeError(f"goodput {goodput} not > 0")
        print(f"# slo gate ok: goodput={goodput:.1f}tok/s, "
              f"{len(met)}/{n} requests in SLO, quantiles within "
              f"{GROWTH:.3f}x of exact")
    return {"goodput_tok_s": goodput, "met": len(met), "n": n,
            "wall_s": wall,
            "ttft_p50_s": h_ttft.quantile(0.5),
            "ttft_p99_s": h_ttft.quantile(0.99),
            "tpot_p50_s": h_tpot.quantile(0.5),
            "tpot_p99_s": h_tpot.quantile(0.99)}


def _ttft_p99(out: dict) -> float:
    """Exact TTFT p99 over *completed* requests (the population the
    no-collapse gate covers — rejected/timed-out requests have no TTFT)."""
    vs = sorted(t for t, o in zip(out["ttft_s"], out["outcomes"])
                if o == "completed" and t is not None)
    if not vs:
        return 0.0
    return vs[min(len(vs) - 1, max(0, math.ceil(0.99 * len(vs)) - 1))]


def _serve(reqs, arrivals=None, **kw):
    """One measured serve run against a clean, enabled registry."""
    was_enabled = obs.enabled()
    obs.configure(enabled=True)
    obs.REGISTRY.reset()
    try:
        return serve_continuous(
            "llama3.2-1b", slots=4, page_size=8, decode_chunk=4,
            requests=reqs, arrival_s=arrivals,
            max_seq_len=max(PROMPT_BUCKETS) + max(GEN_BUCKETS) + 4, **kw)
    finally:
        obs.configure(enabled=was_enabled)


def run_overload(smoke: bool = False, *, seed: int = 0) -> dict:
    """The 2× sustained-overload no-collapse gate (PR 10).

    Self-calibrating so the gate is robust on a shared CI box:

    1. **calibrate** — a closed-loop run over every length-bucket combo
       measures decode capacity ``C`` tok/s and seeds the admission
       policy's prefill/TPOT EMAs (this run also pays the jit compiles
       a cold CI process would otherwise smear into the first scenario);
    2. **capacity** — open-loop at ~0.8×C; its completed-TTFT p99 sets
       the SLO (3×p99, so the compile tail every run pays is inside it)
       and its goodput is the no-overload reference;
    3. **overload-static** — the same workload shape at 2×C with
       per-request TTFT deadlines and a fixed (untuned) policy;
    4. **overload-tuned** — identical, plus the ``ReplanController``'s
       ``AdmissionActuator`` retuning queue-bound/concurrency from
       windowed telemetry on a background thread.

    Gates (``smoke``): every request in both overload runs ends in a
    typed outcome (zero hung); tuned goodput ≥ 70% of capacity-run
    goodput; tuned completed-TTFT p99 within the SLO (no-collapse);
    tuned goodput ≥ 0.9× static (the controller must not lose to the
    policy it tunes — ≥, with a CI noise floor).

    Preemption stays OFF here: resume prefills hit new sequence lengths
    and the per-shape jit recompiles would swamp the timing gates; the
    preempt-resume contract is gated separately (``run_preempt_gate``,
    untimed, bit-exactness not latency).
    """
    from repro.core.admission import AdmissionPolicy

    # 1. calibrate: every (prompt, gen) bucket combo once, closed loop
    calib_reqs = [(p, g) for p in PROMPT_BUCKETS for g in GEN_BUCKETS]
    calib_policy = AdmissionPolicy(slots=4)
    calib = _serve(calib_reqs, admission=calib_policy)
    capacity_tok_s = calib["decode_tok_per_s"]
    seed_tpot = calib_policy.tpot_s
    seed_prefill = calib_policy.prefill_s
    emit("slo_overload_calib", 0.0,
         f"capacity={capacity_tok_s:.1f}tok/s tpot_ema={seed_tpot:.4f}s "
         f"prefill_ema={seed_prefill:.3f}s")

    def workload(n, load_factor, wseed):
        reqs, _ = make_workload(n, seed=wseed)
        mean_gen = sum(g for _, g in reqs) / n
        mean_ia = mean_gen / max(load_factor * capacity_tok_s, 1e-9)
        # steady Poisson (burst_factor=1): the overload is *sustained*
        return make_workload(n, seed=wseed, mean_interarrival_s=mean_ia,
                             burst_factor=1.0, p_flip=0.0)

    # 2. capacity reference at ~0.8×C, no deadlines
    n_cap = 12 if smoke else 24
    cap_reqs, cap_arr = workload(n_cap, 0.8, seed)
    cap = _serve(cap_reqs, cap_arr,
                 admission=AdmissionPolicy(slots=4, tpot_s=seed_tpot,
                                           prefill_s=seed_prefill))
    ttft_slo_s = max(0.3, 3.0 * _ttft_p99(cap))
    goodput_cap = sum(g for (_, g), t in zip(cap_reqs, cap["ttft_s"])
                      if t is not None and t <= ttft_slo_s) \
        / max(cap["wall_s"], 1e-9)
    emit("slo_overload_capacity", 0.0,
         f"goodput={goodput_cap:.1f}tok/s ttft_slo={ttft_slo_s:.2f}s "
         f"({n_cap} requests at 0.8x capacity)")

    # 3./4. the same 2× sustained overload, static vs controller-tuned
    n_over = 20 if smoke else 48
    over_reqs, over_arr = workload(n_over, 2.0, seed + 1)

    def overload_run(tuned: bool) -> dict:
        policy = AdmissionPolicy(slots=4, tpot_s=seed_tpot,
                                 prefill_s=seed_prefill)
        controller = None
        if tuned:
            from repro.core.cost_model import TrainingJob
            from repro.core.profiles import ctrdnn_layers
            from repro.core.replan import (AdmissionActuator, ReplanConfig,
                                           ReplanController)
            from repro.core.resources import default_fleet
            from repro.core.schedulers.rl import RLScheduler
            from repro.obs.bridge import snapshot_resources

            specs = ctrdnn_layers()
            rfleet = default_fleet()
            controller = ReplanController(
                specs, rfleet, TrainingJob(),
                RLScheduler(rounds=10, plans_per_round=8,
                            early_stop_rounds=5, chunk_rounds=5),
                snapshot_fn=lambda: snapshot_resources(rfleet[0]),
                config=ReplanConfig(window_s=0.25,
                                    ttft_slo_s=ttft_slo_s),
                initial=tuple(0 if k in ("embedding", "nce") else 1
                              for k, *_ in specs),
                admission=AdmissionActuator(policy,
                                            ttft_slo_s=ttft_slo_s))
            controller.start(interval_s=0.25)
        try:
            out = _serve(over_reqs, over_arr, admission=policy,
                         deadlines=(ttft_slo_s, None))
        finally:
            if controller is not None:
                controller.stop()
                out["controller"] = controller.report()
        return out

    static = overload_run(tuned=False)
    tuned = overload_run(tuned=True)

    rows = {}
    for name, out in (("static", static), ("tuned", tuned)):
        gp = out["goodput_tok_per_s"]
        p99 = _ttft_p99(out)
        rows[name] = {
            "goodput_tok_s": gp, "ttft_p99_completed_s": p99,
            "outcome_counts": out["outcome_counts"],
            "admission": out["admission"],
        }
        emit(f"slo_overload_{name}", 0.0,
             f"goodput={gp:.1f}tok/s ttft_p99={p99:.3f}s "
             f"outcomes={out['outcome_counts']}")
    if "controller" in tuned:
        adm = tuned["controller"].get("admission", {})
        emit("slo_overload_actuator", 0.0,
             f"breaches={adm.get('breaches')} "
             f"queue_bound={adm.get('queue_bound')} "
             f"max_concurrency={adm.get('max_concurrency')} "
             f"windows={tuned['controller'].get('windows')}")

    goodput_tuned = tuned["goodput_tok_per_s"]
    goodput_static = static["goodput_tok_per_s"]
    if smoke:
        for name, out in (("static", static), ("tuned", tuned)):
            if any(o is None for o in out["outcomes"]):
                raise RuntimeError(f"{name}: hung request without outcome")
        if goodput_tuned < 0.7 * goodput_cap:
            raise RuntimeError(
                f"overload collapse: tuned goodput {goodput_tuned:.1f} < "
                f"70% of capacity goodput {goodput_cap:.1f}")
        p99_tuned = rows["tuned"]["ttft_p99_completed_s"]
        if p99_tuned > ttft_slo_s:
            raise RuntimeError(
                f"admitted-TTFT collapse: p99 {p99_tuned:.3f}s > "
                f"SLO {ttft_slo_s:.3f}s under overload")
        if goodput_tuned < 0.9 * goodput_static:
            raise RuntimeError(
                f"controller hurt goodput: tuned {goodput_tuned:.1f} < "
                f"0.9x static {goodput_static:.1f}")
        print(f"# slo overload gate ok: tuned={goodput_tuned:.1f}tok/s "
              f"(capacity={goodput_cap:.1f}, static={goodput_static:.1f}), "
              f"ttft_p99={rows['tuned']['ttft_p99_completed_s']:.3f}s "
              f"<= slo={ttft_slo_s:.2f}s")
    return {"capacity_tok_s": capacity_tok_s,
            "goodput_capacity_tok_s": goodput_cap,
            "ttft_slo_s": ttft_slo_s,
            "static": rows["static"], "tuned": rows["tuned"]}


def run_preempt_gate() -> dict:
    """Preempt-and-resume bit-exactness gate: r1 (small) preempts r0
    (large remaining) under page pressure; r0 resumes by prefilling
    prompt+generated — its stream must equal a solo un-preempted run.
    Untimed: correctness only, so jit recompiles cannot flake it."""
    kw = dict(page_size=4, decode_chunk=4, max_seq_len=36, num_pages=13)
    was_enabled = obs.enabled()
    obs.configure(enabled=True)
    obs.REGISTRY.reset()
    try:
        out = serve_continuous("llama3.2-1b", slots=2,
                               requests=[(8, 24), (8, 4)],
                               preemption=True, **kw)
        solo = serve_continuous("llama3.2-1b", slots=1, requests=[(8, 24)],
                                **kw)
    finally:
        obs.configure(enabled=was_enabled)
    if out["outcomes"] != ["completed", "completed"]:
        raise RuntimeError(f"preempt outcomes: {out['outcomes']}")
    if out["preemptions"] < 1 or out["resumes"] < 1:
        raise RuntimeError(
            f"scenario did not preempt: {out['preemptions']} preemptions, "
            f"{out['resumes']} resumes")
    if not out["pool_conserved"]:
        raise RuntimeError("page pool not conserved across preempt/resume")
    if out["tokens"][0] != solo["tokens"][0]:
        raise RuntimeError("resumed stream differs from un-preempted run")
    emit("slo_preempt_gate", 0.0,
         f"bit-exact resume ok ({out['preemptions']} preemption, "
         f"{out['resumes']} resume, 24+4 tokens)")
    return {"preemptions": out["preemptions"], "resumes": out["resumes"],
            "bit_exact": True}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: small workload + goodput/quantile gates")
    ap.add_argument("--scenario", choices=("base", "overload"),
                    default="base",
                    help="base: the open-loop SLO harness; overload: the "
                         "2x sustained-overload no-collapse gate plus the "
                         "preempt-resume bit-exactness gate")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ttft-slo", type=float, default=30.0,
                    help="TTFT SLO seconds (generous: first request per "
                         "length bucket pays jit compile)")
    ap.add_argument("--tpot-slo", type=float, default=1.0,
                    help="per-output-token SLO seconds")
    ap.add_argument("--obs-dir", default=None,
                    help="also write trace.json + metrics.jsonl here")
    args = ap.parse_args()
    if args.obs_dir:
        obs.configure(run_dir=args.obs_dir)
    print("name,us_per_call,derived")
    t0 = time.time()
    try:
        if args.scenario == "overload":
            summary = {"overload": run_overload(smoke=args.smoke,
                                                seed=args.seed),
                       "preempt": run_preempt_gate()}
        else:
            summary = run(smoke=args.smoke, n_requests=args.requests,
                          seed=args.seed, ttft_slo_s=args.ttft_slo,
                          tpot_slo_s=args.tpot_slo)
    except BaseException as e:
        write_artifact("slo", ok=False, error=repr(e),
                       seconds=time.time() - t0)
        raise
    if args.obs_dir:
        summary["obs"] = obs.flush()
    write_artifact("slo", ok=True, seconds=time.time() - t0, extra=summary)


if __name__ == "__main__":
    main()
