"""Paper Figs. 8–10: cost (and throughput-constraint satisfaction) per
scheduling method across the four paper models — RL-LSTM should win or
tie everywhere; CPU fails the constraint for CTRDNN (Fig. 10)."""

from __future__ import annotations

import math

from benchmarks.common import emit, fmt_cost
from repro.core import (
    TrainingJob, build_stages, default_fleet, paper_model_profiles,
    pipeline_throughput,
)
from repro.core.schedulers import ALL_SCHEDULERS

JOB = TrainingJob()
FLEET = default_fleet()
METHODS = ("RL-LSTM", "RL-RNN", "BO", "Genetic", "Greedy", "GPU", "CPU",
           "Heuristic")


def run() -> None:
    for model in ("MATCHNET", "CTRDNN", "2EMB", "NCE"):
        profs = paper_model_profiles(model, FLEET)
        for name in METHODS:
            kw = {"rounds": 50} if name.startswith("RL") else {}
            r = ALL_SCHEDULERS[name](**kw).schedule(profs, FLEET, JOB)
            # Fig. 7/10 companion: normalized throughput (≥1 = meets limit)
            if r.prov is not None:
                stages = build_stages(r.plan, profs, FLEET)
                tp = pipeline_throughput(stages, r.prov, JOB.batch_size)
                norm_tp = tp / JOB.throughput_limit
            else:
                norm_tp = 0.0  # constraint not satisfiable (paper Fig. 10 CPU)
            emit(f"fig8/{model}/{name}", r.wall_time_s * 1e6,
                 f"cost={fmt_cost(r.cost)};norm_tp={norm_tp:.2f}")
