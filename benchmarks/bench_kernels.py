"""Kernel microbenchmarks: XLA reference wall time per shape + interpret-
mode max-abs error of the Pallas kernel vs the oracle (real-TPU timing is
out of scope on this CPU container; the error column proves correctness)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ref
from repro.kernels.embedding_bag import embedding_bag
from repro.kernels.flash_attention import flash_attention

KEY = jax.random.PRNGKey(0)


def run() -> None:
    for B, H, S, hd in ((1, 4, 512, 64), (2, 8, 1024, 128)):
        q = jax.random.normal(KEY, (B, H, S, hd))
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, H, S, hd))
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, H, S, hd))
        fn = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c, causal=True))
        fn(q, k, v).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            fn(q, k, v).block_until_ready()
        us = (time.perf_counter() - t0) / 5 * 1e6
        err = float(jnp.abs(
            flash_attention(q, k, v, causal=True, interpret=True)
            - ref.flash_attention_ref(q, k, v, causal=True)
        ).max())
        emit(f"kernel/flash_attn/B{B}H{H}S{S}hd{hd}", us, f"maxerr={err:.2e}")

    for N, bag, V, dim in ((64, 16, 10_000, 128), (256, 26, 100_000, 128)):
        ids = jax.random.randint(KEY, (N, bag), 0, V)
        table = jax.random.normal(KEY, (V, dim))
        fn = jax.jit(ref.embedding_bag_ref)
        fn(ids, table).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(10):
            fn(ids, table).block_until_ready()
        us = (time.perf_counter() - t0) / 10 * 1e6
        err = float(jnp.abs(embedding_bag(ids, table, interpret=True)
                            - ref.embedding_bag_ref(ids, table)).max())
        emit(f"kernel/embedding_bag/N{N}bag{bag}", us, f"maxerr={err:.2e}")
