"""Kernel microbenchmarks: XLA reference wall time per shape + interpret-
mode max-abs error of the Pallas kernel vs the oracle (real-TPU timing is
out of scope on this CPU container; the error column proves correctness).

The MoE section additionally *gates* a real speedup: the fused-layout
slot formulation (the same algorithm the Pallas kernels run, executed as
jnp gathers on CPU) must beat the reference scatter/gather
dispatch+combine round-trip.  ``--smoke`` runs just that gate for CI
(exits nonzero below ``MOE_GATE``×).

  PYTHONPATH=src python benchmarks/bench_kernels.py [--smoke]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

try:
    from benchmarks.common import emit
except ImportError:  # run directly: python benchmarks/bench_kernels.py
    from common import emit
from repro.kernels import moe as moe_k
from repro.kernels import ref
from repro.kernels.embedding_bag import embedding_bag
from repro.kernels.flash_attention import flash_attention
from repro.nn import moe as moe_mod

KEY = jax.random.PRNGKey(0)

#: CI gate: fused-layout dispatch+combine vs the reference scatter/gather
#: round-trip.  The gate shape measures 3.9–6.8× on the CPU container
#: (smaller shapes swing 1.4–2.7× under scheduler noise — too flaky to
#: gate), so 1.5× leaves a wide margin for CI jitter.
MOE_GATE = 1.5
MOE_GATE_SHAPE = (4, 1024, 512, 16, 2)      # (G, S, D, E, K)


def _timeit(fn, *args, iters: int = 10) -> float:
    jax.block_until_ready(fn(*args))        # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def _moe_roundtrips(G, S, D, E, K, cf=1.25):
    """Build jitted ref / slot dispatch+combine round-trips + err probe."""
    p = moe_mod.init_moe(KEY, D, 2 * D, E)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (G, S, D))
    C = moe_mod.moe_capacity(S, E, K, cf)
    _, gate, eid_f, pos, keep = moe_mod.moe_route(p["router"], x, top_k=K,
                                                  capacity=C)
    safe_pos = jnp.where(keep, pos, 0)
    w = (gate.reshape(G, S, K) * keep.reshape(G, S, K))
    keepf = keep.astype(jnp.float32)
    eid3 = eid_f.reshape(G, S, K)
    pos3 = safe_pos.reshape(G, S, K)

    @jax.jit
    def rt_ref(x):
        buf = moe_mod.ref_dispatch(x, eid_f, safe_pos, keep, num_experts=E,
                                   capacity=C, top_k=K)
        return moe_mod.ref_combine(buf, eid_f, safe_pos,
                                   w.reshape(G, S * K), top_k=K)

    @jax.jit
    def rt_slot(x):
        buf = moe_k.moe_dispatch(x, eid_f, pos, keepf, E, C, K, "slot")
        return moe_k.moe_combine(buf, eid3, pos3, w, "slot")

    def rt_interpret(x):
        buf = moe_k.moe_dispatch(x, eid_f, pos, keepf, E, C, K, "interpret")
        return moe_k.moe_combine(buf, eid3, pos3, w, "interpret")

    return x, rt_ref, rt_slot, rt_interpret


def run_moe(*, smoke: bool = False) -> None:
    shapes = [MOE_GATE_SHAPE] if smoke else [
        (8, 512, 256, 8, 2), MOE_GATE_SHAPE, (8, 256, 256, 64, 8),
    ]
    for G, S, D, E, K in shapes:
        x, rt_ref, rt_slot, rt_interpret = _moe_roundtrips(G, S, D, E, K)
        us_ref = _timeit(rt_ref, x)
        us_slot = _timeit(rt_slot, x)
        speedup = us_ref / us_slot
        err = float(jnp.abs(rt_slot(x) - rt_ref(x)).max())
        emit(f"kernel/moe_rt_ref/G{G}S{S}D{D}E{E}K{K}", us_ref,
             f"maxerr={err:.2e}")
        emit(f"kernel/moe_rt_fused/G{G}S{S}D{D}E{E}K{K}", us_slot,
             f"speedup={speedup:.2f}x")
        if (G, S, D, E, K) == MOE_GATE_SHAPE and smoke:
            if speedup < MOE_GATE:
                raise SystemExit(
                    f"fused MoE dispatch+combine speedup {speedup:.2f}x "
                    f"below the {MOE_GATE}x gate")
            print(f"# moe gate ok: {speedup:.2f}x >= {MOE_GATE}x")
    if not smoke:
        # interpret-mode correctness probe on a small shape (slow path)
        G, S, D, E, K = 2, 32, 64, 4, 2
        x, rt_ref, _, rt_interpret = _moe_roundtrips(G, S, D, E, K)
        err = float(jnp.abs(rt_interpret(x) - rt_ref(x)).max())
        emit(f"kernel/moe_interpret/G{G}S{S}D{D}E{E}K{K}", 0.0,
             f"maxerr={err:.2e}")


def run(smoke: bool = False) -> None:
    if smoke:
        run_moe(smoke=True)
        return
    for B, H, S, hd in ((1, 4, 512, 64), (2, 8, 1024, 128)):
        q = jax.random.normal(KEY, (B, H, S, hd))
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, H, S, hd))
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, H, S, hd))
        fn = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c, causal=True))
        fn(q, k, v).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            fn(q, k, v).block_until_ready()
        us = (time.perf_counter() - t0) / 5 * 1e6
        err = float(jnp.abs(
            flash_attention(q, k, v, causal=True, interpret=True)
            - ref.flash_attention_ref(q, k, v, causal=True)
        ).max())
        emit(f"kernel/flash_attn/B{B}H{H}S{S}hd{hd}", us, f"maxerr={err:.2e}")

    for N, bag, V, dim in ((64, 16, 10_000, 128), (256, 26, 100_000, 128)):
        ids = jax.random.randint(KEY, (N, bag), 0, V)
        table = jax.random.normal(KEY, (V, dim))
        fn = jax.jit(ref.embedding_bag_ref)
        fn(ids, table).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(10):
            fn(ids, table).block_until_ready()
        us = (time.perf_counter() - t0) / 10 * 1e6
        err = float(jnp.abs(embedding_bag(ids, table, interpret=True)
                            - ref.embedding_bag_ref(ids, table)).max())
        emit(f"kernel/embedding_bag/N{N}bag{bag}", us, f"maxerr={err:.2e}")

    run_moe(smoke=False)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: just the gated fused-MoE speedup check")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)
