"""Reactive re-planning benchmark: drift recovery vs a frozen plan.

Two gated scenarios over :class:`repro.core.replan.ReplanController`:

* **shard-kill** — the real loop: the elastic CTR trainer runs with the
  controller attached (``train_ctr_elastic(replan=...)``) and a PS shard
  is hard-killed mid-run.  The kill (an *edge* signal: fleet lifecycle
  event + degraded rising edge) must produce **exactly one** drift
  consideration — not zero (the loop is closed), not several (cooldown +
  re-anchoring prevent flapping) — and the warm-started candidate must
  never cost more than the incumbent it was seeded with.

* **load-shift** — the measurement half synthesized, everything from the
  detector inward real: snapshots carry nominal CPU-side bandwidth, the
  controller calibrates, then bandwidth collapses to ``SHIFT_SCALE``×.
  The drifted windows trigger one re-plan; the re-planned assignment is
  compared against (a) the **frozen** pre-shift plan scored on the live
  profiles and (b) an **oracle** fresh search on the same live profiles.
  Gate: ``recovery = (frozen - reactive) / (frozen - oracle) >= 0.5`` —
  the controller must close at least half the cost gap drift opened
  (warm-start anchoring makes this structural: the search result is
  best-of {incumbent, anchors, search}, so reactive <= frozen always,
  and the homogeneous anchors already contain the post-shift optimum).

  PYTHONPATH=src python benchmarks/bench_replan.py [--smoke]
  PYTHONPATH=src python -m benchmarks.run --only replan
"""

from __future__ import annotations

import argparse
import time

try:
    from benchmarks.common import emit, write_artifact
except ImportError:   # direct `python benchmarks/bench_replan.py` run
    from common import emit, write_artifact

#: post-shift CPU bandwidth scale.  0.15x is calibrated so the CTR-DNN
#: optimum genuinely flips (embedding off the starved CPU) while the
#: pre-shift plan stays feasible — a finite, nonzero recovery gap.
SHIFT_SCALE = 0.15


def _small_scheduler():
    from repro.core.schedulers.rl import RLScheduler

    # warm-start anchoring bounds the result, so a small fused budget
    # is enough for the bench's in-loop searches
    return RLScheduler(rounds=40, plans_per_round=16, early_stop_rounds=15,
                       chunk_rounds=10, seed=0)


def bench_shard_kill(*, steps: int, kill_step: int) -> None:
    from repro.core.replan import ReplanConfig, ctr_replan_factory
    from repro.ps.workload import CTRConfig, train_ctr_elastic

    cfg = CTRConfig(vocab=5_000, emb_dim=8, slots=8, tower=(32,), batch=64)
    # bw_tolerance is parked high: in-process bandwidth jitter is real
    # but not the signal under test — this scenario gates the *event*
    # path (kill -> exactly one replan consideration)
    rcfg = ReplanConfig(window_steps=5, bw_tolerance=5.0,
                        cooldown_windows=2, hysteresis_windows=2)
    factory = ctr_replan_factory(rcfg, scheduler=_small_scheduler())
    t0 = time.perf_counter()
    out = train_ctr_elastic(cfg, steps=steps, num_shards=3,
                            optimizer="adagrad", mode="sync",
                            events=[(kill_step, "kill", 0)], replan=factory)
    wall = time.perf_counter() - t0
    rep = out["replan"]
    drift = [d for d in rep["decisions"] if d["kind"] == "drift"]
    emit("replan_kill_considered", float(rep["considered"]),
         f"{rep['windows']} windows, {rep['calibrations']} calibration(s), "
         f"{rep['considered']} drift consideration(s), "
         f"{rep['applied']} applied, wall {wall:.1f}s")
    if out["steps"] != steps:
        raise RuntimeError(f"training truncated: {out['steps']}/{steps} "
                           f"steps with the controller attached")
    if rep["considered"] != 1:
        raise RuntimeError(
            f"shard kill must trigger exactly one replan consideration, "
            f"got {rep['considered']} (decisions: {rep['decisions']})")
    d = drift[0]
    if not (set(d["reasons"]) & {"fleet_events", "ps_degraded"}):
        raise RuntimeError(f"drift reasons miss the kill edge: {d}")
    # warm-start guarantee: candidate never worse than the incumbent it
    # was seeded with, both scored on the same live profiles
    if d["candidate_cost"] > d["incumbent_cost"] * (1 + 1e-9):
        raise RuntimeError(
            f"warm-started candidate ({d['candidate_cost']:.3f}) worse "
            f"than incumbent ({d['incumbent_cost']:.3f})")
    emit("replan_kill_costs", d["candidate_cost"],
         f"incumbent {d['incumbent_cost']:.3f} -> candidate "
         f"{d['candidate_cost']:.3f}, reasons {d['reasons']}")


def _shift_snapshot(cum: dict, base, scale: float) -> dict:
    """Advance cumulative fake PS traffic by one window at ``scale``x the
    nominal bandwidths and return the snapshot_resources-shaped dict."""
    # one second of pull + one of push per window, bytes chosen so the
    # windowed rates land exactly on scale * (ingest_bw, net_bw)
    pull_b = scale * base.ingest_bw
    push_b = 2 * scale * base.net_bw - pull_b
    cum["pull_b"] += pull_b
    cum["pull_s"] += 1.0
    cum["push_b"] += push_b
    cum["push_s"] += 1.0
    return {
        "resource": base, "embedding_odt": (0.0, 0.0),
        "serve": {"queue_depth": 0.0, "tokens": 0.0},
        "ps": {"pull": {"bytes": cum["pull_b"], "seconds": cum["pull_s"],
                        "rows": 0},
               "push": {"bytes": cum["push_b"], "seconds": cum["push_s"],
                        "rows": 0}},
    }


def bench_load_shift(*, settle_windows: int = 3) -> None:
    from repro.core.cost_model import TrainingJob, plan_cost
    from repro.core.plan import SchedulingPlan
    from repro.core.profiles import ctrdnn_layers
    from repro.core.replan import ReplanConfig, ReplanController
    from repro.core.resources import default_fleet

    fleet = default_fleet()
    job = TrainingJob()
    specs = ctrdnn_layers()
    sched = _small_scheduler()
    clock = {"t": 0.0}
    cfg = ReplanConfig(window_steps=1, bw_tolerance=0.5,
                       hysteresis_windows=2, cooldown_windows=3,
                       switch_margin=0.05)
    ctl = ReplanController(specs, fleet, job, sched,
                           snapshot_fn=lambda: None, config=cfg,
                           clock=lambda: clock["t"])
    frozen_assignment = ctl.incumbent.assignment
    cum = {"pull_b": 0.0, "pull_s": 0.0, "push_b": 0.0, "push_s": 0.0}
    base = fleet[0]

    def window(scale: float):
        clock["t"] += 5.0
        return ctl.observe(snapshot=_shift_snapshot(cum, base, scale))

    t0 = time.perf_counter()
    window(1.0)                                # opens the first window
    window(1.0)                                # calibration at nominal
    for _ in range(settle_windows):
        if window(1.0) is not None:
            raise RuntimeError("controller re-planned in steady state")
    shift_decisions = [window(SHIFT_SCALE) for _ in range(8)]
    wall = time.perf_counter() - t0
    rep = ctl.report()
    fired = [d for d in shift_decisions if d is not None]
    if rep["considered"] != 1 or len(fired) != 1:
        raise RuntimeError(
            f"sustained load shift must trigger exactly one replan, got "
            f"considered={rep['considered']} (decisions: "
            f"{rep['decisions']})")
    if rep["applied"] != 1:
        raise RuntimeError(
            f"the load-shift replan was not applied: {fired[0]}")

    # score frozen / reactive / oracle on the SAME live context the
    # controller re-planned against (stored in the incumbent)
    live_profiles = ctl.incumbent.profiles
    live_fleet = ctl.incumbent.fleet
    frozen_cost, _ = plan_cost(SchedulingPlan(frozen_assignment),
                               live_profiles, live_fleet, job)
    reactive_cost = ctl.incumbent.cost
    oracle = sched.schedule_many([(live_profiles, live_fleet, job)])[0]
    gap = frozen_cost - oracle.cost
    recovery = (frozen_cost - reactive_cost) / gap if gap > 0 else 1.0
    emit("replan_shift_recovery", recovery,
         f"frozen {frozen_cost:.3f} / reactive {reactive_cost:.3f} / "
         f"oracle {oracle.cost:.3f} at {SHIFT_SCALE}x bandwidth, "
         f"recovered {recovery * 100:.0f}% of the gap, wall {wall:.1f}s")
    if gap <= 0:
        raise RuntimeError(
            f"degenerate scenario: frozen ({frozen_cost:.3f}) not worse "
            f"than oracle ({oracle.cost:.3f}) after the shift")
    if recovery < 0.5:
        raise RuntimeError(
            f"reactive replan recovered only {recovery * 100:.0f}% of the "
            f"frozen->oracle gap (gate: >= 50%)")
    # and the plan really changed
    if tuple(fired[0]["to"]) == tuple(frozen_assignment):
        raise RuntimeError("shift replan kept the frozen assignment")


def run(smoke: bool = False) -> None:
    bench_shard_kill(steps=30 if smoke else 60,
                     kill_step=15 if smoke else 30)
    bench_load_shift()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI (<1 min)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    t0 = time.time()
    try:
        run(smoke=args.smoke)
    except BaseException as e:
        write_artifact("replan", ok=False, error=repr(e),
                       seconds=time.time() - t0)
        raise
    write_artifact("replan", ok=True, seconds=time.time() - t0)


if __name__ == "__main__":
    main()
