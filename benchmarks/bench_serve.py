"""Serving benchmark: decode throughput + KV bytes/token.

Measures, at equal arch/batch/lengths:

* the **pre-PR decode loop** (a jit dispatch + ``np.asarray`` host sync
  per generated token — ``GateHarness.run_legacy`` reproduces it as the
  baseline);
* the **fused serve path** (ONE batched prefill forward + a jitted
  ``lax.scan`` decode loop harvesting tokens on device), dense and paged
  — both measured from the *same* compiled programs and post-prefill
  state as the baseline, so only the decode region differs;
* the **continuous-batching** loop's measured KV bytes/token with a
  skewed request mix (short sequences in a long-capacity pool), paged vs
  the dense-equivalent accounting.

``--smoke`` runs the two CI gates: fused decode tok/s ≥ ``SERVE_GATE``×
the legacy loop, and paged KV bytes/token < dense on the skewed mix.

  PYTHONPATH=src python benchmarks/bench_serve.py [--smoke]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import emit, sync, write_artifact
except ImportError:  # run directly: python benchmarks/bench_serve.py
    from common import emit, sync, write_artifact
from repro.configs import get_config
from repro.launch.serve import serve, serve_continuous
from repro.models import decoder as dec

#: CI gate: fused decode loop vs the pre-PR per-token serve loop.  On a
#: quiet machine the gate shape measures ~1.6–2.3× (the reduced models
#: are small enough that per-token jit dispatch + host sync are a large,
#: fixed slice of the old loop's step); under scheduler contention single
#: runs swing ±50%, so the gate takes the best of ``GATE_ATTEMPTS``
#: interleaved fused/legacy pairs — noise only ever *lowers* a pair's
#: ratio, so the max over pairs approximates the uncontended speedup.
SERVE_GATE = 1.5
GATE_ATTEMPTS = 4
#: cache_len must cover prompt+gen: the paged pool does not ring-wrap
GATE_SHAPE = dict(arch="gemma2-2b", batch=4, prompt_len=8, gen=32,
                  cache_len=64)


class GateHarness:
    """Compile-once fused-vs-legacy decode harness: one model, one
    prefilled cache, one jitted ``decode_step`` and one jitted
    ``decode_loop`` — every gate attempt re-measures only the decode
    region (both paths start from the *same* post-prefill state, so
    their tokens must agree exactly)."""

    def __init__(self, *, arch: str, batch: int, prompt_len: int, gen: int,
                 cache_len: int, chunk: int = 8, seed: int = 0):
        self.B, self.plen, self.gen, self.chunk = batch, prompt_len, gen, chunk
        cfg = self.cfg = get_config(arch, reduced=True)
        key = jax.random.PRNGKey(seed)
        self.params = dec.init_model(cfg, key)
        prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)
        self.step = jax.jit(
            lambda p, t, c, i: dec.decode_step(p, cfg, t, c, i,
                                               compute_dtype=jnp.float32))
        self.loop = jax.jit(
            lambda p, t, c, i: dec.decode_loop(p, cfg, t, c, i, chunk,
                                               compute_dtype=jnp.float32))
        cache = dec.init_cache(cfg, batch, cache_len, dtype=jnp.float32)
        lg, self.cache0 = jax.jit(
            lambda p, t, c: dec.prefill(p, cfg, t, c,
                                        compute_dtype=jnp.float32)
        )(self.params, prompts, cache)
        self.tok0 = jnp.argmax(lg[:, -1:, :cfg.vocab], -1).astype(jnp.int32)
        # warm both decode programs (functional: discarded runs leave the
        # start state untouched)
        jax.block_until_ready(
            self.step(self.params, self.tok0, self.cache0,
                      jnp.int32(prompt_len))[0])
        jax.block_until_ready(
            self.loop(self.params, self.tok0, self.cache0,
                      jnp.int32(prompt_len))[0])

    def run_legacy(self):
        """The pre-PR decode loop: one jit dispatch + argmax dispatch +
        ``np.asarray`` host sync per generated token."""
        tok, cache = self.tok0, self.cache0
        generated = []
        t0 = time.time()
        for i in range(self.gen):
            generated.append(np.asarray(tok)[:, 0])   # per-token host sync
            logits, cache = self.step(self.params, tok, cache,
                                      jnp.int32(self.plen + i))
            tok = jnp.argmax(logits[:, :, :self.cfg.vocab],
                             axis=-1).astype(jnp.int32)
        sync(tok)        # the last step's dispatch must land inside t0..t1
        return np.stack(generated, axis=1), time.time() - t0

    def run_fused(self):
        """The new path: jitted multi-token chunks, one harvest each."""
        tok, cache, idx = self.tok0, self.cache0, self.plen
        outs = []
        t0 = time.time()
        for _ in range(self.gen // self.chunk):
            toks, tok, cache = self.loop(self.params, tok, cache,
                                         jnp.int32(idx))
            outs.append(np.asarray(toks))
            idx += self.chunk
        sync(tok)        # fence the final chunk's next-token dispatch
        return np.concatenate(outs, axis=1), time.time() - t0


#: skewed mix: short sequences in a pool provisioned for much longer ones
SKEW_REQUESTS = [(6, 6), (10, 8), (4, 6), (14, 8), (8, 4), (5, 7)]
SKEW_POOL_LEN = 256


def run_skew(*, smoke: bool = False) -> None:
    out = serve_continuous(
        "llama3.2-1b", slots=4, page_size=8, decode_chunk=4,
        requests=SKEW_REQUESTS, max_seq_len=SKEW_POOL_LEN,
    )
    ratio = out["kv_bytes_per_token_paged"] / out["kv_bytes_per_token_dense"]
    emit("serve/continuous_paged_kv_bytes_per_tok",
         out["kv_bytes_per_token_paged"],
         f"dense_equiv={out['kv_bytes_per_token_dense']:.0f};"
         f"ratio={ratio:.3f};tok_per_s={out['decode_tok_per_s']:.1f}")
    assert out["pool_conserved"], "page pool leaked pages"
    if smoke and ratio >= 1.0:
        raise SystemExit(
            f"paged KV bytes/token ratio {ratio:.3f} not below dense")
    if smoke:
        print(f"# serve kv gate ok: paged/dense bytes = {ratio:.3f} < 1")


def run_gate(*, smoke: bool = False) -> None:
    h = GateHarness(**GATE_SHAPE)
    B, gen = GATE_SHAPE["batch"], GATE_SHAPE["gen"]
    best = 0.0
    for attempt in range(GATE_ATTEMPTS):
        f_toks, f_s = h.run_fused()
        l_toks, l_s = h.run_legacy()
        if attempt == 0:
            assert (f_toks == l_toks).all(), \
                "fused loop changed the generated tokens"
            paged = serve(**GATE_SHAPE, reduced=True, decode_chunk=8,
                          kv_impl="paged", page_size=8)
            assert paged["tokens"] == l_toks.tolist(), \
                "paged path changed the generated tokens"
            emit("serve/legacy_decode", l_s / (B * gen) * 1e6,
                 f"tok_per_s={B * gen / l_s:.1f}")
            emit("serve/fused_dense_decode", f_s / (B * gen) * 1e6,
                 f"tok_per_s={B * gen / f_s:.1f}")
            emit("serve/fused_paged_decode",
                 paged["decode_s"] / (B * gen) * 1e6,
                 f"tok_per_s={paged['decode_tok_per_s']:.1f}")
        best = max(best, l_s / f_s)
        if best >= SERVE_GATE:
            break
    emit("serve/fused_vs_legacy", 0.0,
         f"speedup={best:.2f}x;attempts={attempt + 1}")
    if smoke and best < SERVE_GATE:
        raise SystemExit(
            f"fused serve decode speedup {best:.2f}x below the "
            f"{SERVE_GATE}x gate")
    if smoke:
        print(f"# serve gate ok: {best:.2f}x >= {SERVE_GATE}x")


def run(smoke: bool = False) -> None:
    run_gate(smoke=smoke)
    run_skew(smoke=smoke)
    if smoke:
        return
    # full sweep: per-arch fused serve across the cache families
    for arch in ("llama3.2-1b", "gemma2-2b", "rwkv6-7b", "jamba-v0.1-52b"):
        r = serve(arch, reduced=True, batch=4, prompt_len=16, gen=16,
                  cache_len=64, decode_chunk=8)
        emit(f"serve/fused/{arch}", r["decode_s"] / (4 * 16) * 1e6,
             f"tok_per_s={r['decode_tok_per_s']:.1f};"
             f"prefill_s={r['prefill_s']:.2f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: gated fused-vs-legacy speedup + paged "
                         "KV bytes check")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    t0 = time.time()
    try:
        run(smoke=args.smoke)
    except BaseException as e:
        write_artifact("serve", ok=False, error=repr(e),
                       seconds=time.time() - t0)
        raise
    write_artifact("serve", ok=True, seconds=time.time() - t0)
