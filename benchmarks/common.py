"""Shared benchmark helpers: CSV emission + standard fleet/job setup."""

from __future__ import annotations

import sys
import time


def emit(name: str, us_per_call: float, derived) -> None:
    """The run.py contract: ``name,us_per_call,derived`` CSV rows."""
    print(f"{name},{us_per_call:.1f},{derived}")
    sys.stdout.flush()


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def fmt_cost(c: float) -> str:
    import math

    return f"{c:.3f}" if math.isfinite(c) else "infeasible"
