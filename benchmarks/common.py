"""Shared benchmark helpers: CSV emission, device sync, JSON artifacts."""

from __future__ import annotations

import json
import os
import sys
import time

#: rows emitted since the last reset — serialized into BENCH_<suite>.json
_ROWS: list[dict] = []


def emit(name: str, us_per_call: float, derived) -> None:
    """The run.py contract: ``name,us_per_call,derived`` CSV rows."""
    _ROWS.append({"name": name, "us_per_call": float(us_per_call),
                  "derived": str(derived)})
    print(f"{name},{us_per_call:.1f},{derived}")
    sys.stdout.flush()


def reset_rows() -> None:
    """Start a fresh row window (run.py calls this per suite)."""
    _ROWS.clear()


def sync(x):
    """``jax.block_until_ready`` on ``x`` (pytrees fine) — the fence every
    timed region needs so the timer sees finished device work, not queued
    dispatches.  Identity for host-only values / when jax is absent."""
    try:
        import jax
    except ImportError:
        return x
    return jax.block_until_ready(x)


def write_artifact(suite: str, *, ok: bool, error: str | None = None,
                   seconds: float | None = None,
                   extra: dict | None = None) -> str:
    """Write the machine-readable ``BENCH_<suite>.json`` artifact: every
    ``emit`` row since the last reset plus pass/fail — what CI uploads.
    Directory comes from ``BENCH_ARTIFACT_DIR`` (default: cwd)."""
    out_dir = os.environ.get("BENCH_ARTIFACT_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{suite}.json")
    payload = {"suite": suite, "ok": bool(ok), "error": error,
               "seconds": seconds, "unix_ts": time.time(),
               "rows": list(_ROWS)}
    if extra:
        payload["extra"] = extra
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
    return path


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def fmt_cost(c: float) -> str:
    import math

    return f"{c:.3f}" if math.isfinite(c) else "infeasible"
