"""Benchmark harness — one module per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV (benchmarks/common.emit) and
writes one machine-readable ``BENCH_<name>.json`` artifact per suite
(rows + pass/fail + failure text; see benchmarks/common.write_artifact),
which CI uploads.

  PYTHONPATH=src python -m benchmarks.run [--only table2,fig4,...]
"""

from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

from benchmarks import common

SUITES = [
    ("table2", "benchmarks.bench_table2_bf_vs_rl"),
    ("table3", "benchmarks.bench_table3_sched_time"),
    ("fig4", "benchmarks.bench_fig4_provisioning"),
    ("fig5", "benchmarks.bench_fig5_cost_methods"),
    ("fig8", "benchmarks.bench_fig8_cost_models"),
    ("fig12", "benchmarks.bench_fig12_pipeline"),
    ("roofline", "benchmarks.bench_roofline"),
    ("kernels", "benchmarks.bench_kernels"),
    ("ps", "benchmarks.bench_ps"),
    ("chaos", "benchmarks.bench_chaos"),
    ("serve", "benchmarks.bench_serve"),
    ("slo", "benchmarks.bench_slo"),
    ("slo-overload", "benchmarks.bench_slo_overload"),
    ("replan", "benchmarks.bench_replan"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for name, module in SUITES:
        if only and name not in only:
            continue
        t0 = time.time()
        common.reset_rows()
        try:
            mod = importlib.import_module(module)
            mod.run()
            common.write_artifact(name, ok=True, seconds=time.time() - t0)
            print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            err = traceback.format_exc(limit=16)
            common.write_artifact(name, ok=False, error=err,
                                  seconds=time.time() - t0)
            print(f"# {name} FAILED:\n{err}", file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
