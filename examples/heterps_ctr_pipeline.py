"""End-to-end HeterPS driver: CTR model with the full distributed stack.

This is the paper's own workload (§6): a CTR model with a huge sparse
embedding feeding a dense tower, trained on a streaming synthetic click
log with:

* RL-LSTM scheduling of the layer→resource-type plan (and the plan's
  stage partition driving the pipeline split),
* a **sharded parameter server** (``repro.ps``) holding the embedding
  table across 4 PS shards — the async ``PSClient`` double-buffers
  pulls/pushes around the compute (while step *i* computes, batch
  *i+1*'s rows are pulled and step *i−1*'s row grads pushed),
* GPipe-style pipeline parallelism over the dense-tower stages
  (shard_map + ppermute; with one CPU device the stage mesh is 1-wide —
  run with XLA_FLAGS=--xla_force_host_platform_device_count=4 to see the
  real 4-stage pipeline),
* the data-management access monitor deciding hot/warm/cold row tiers
  and the ``TierPlacer`` re-pinning them every 50 steps,
* prefetching input pipeline, per-shard pull/push telemetry.

Trains ~65M parameters for a few hundred steps; logloss decreases.

Run:  PYTHONPATH=src python examples/heterps_ctr_pipeline.py [--steps 300]

The PS-focused slice of this stack (without the pipeline) also runs via
the launcher's ``--sparse-ps`` mode, which now fronts the *elastic*
multi-process fleet:

  PYTHONPATH=src python -m repro.launch.train --sparse-ps \
      --ps-transport multiproc      # real shard worker processes \
      --ps-optimizer adagrad        # PS-hosted adaptive optimizer \
      --ps-event 100:join --ps-event 200:kill:0   # elasticity faults

``--ps-transport inproc`` (default) keeps every shard in-process and
bit-exact vs the oracle; ``multiproc`` spawns one numpy-only worker per
shard behind pipes.  With ``--ps-optimizer`` other than ``none`` the
shards apply sgd/adagrad/adam themselves from deduped raw gradients
(one update per row per step), replicate synchronously, and survive
``--ps-event STEP:kill:SHARD`` fault injection losslessly — the loss
trajectory matches the uninterrupted run exactly (see DESIGN.md,
"Multi-process elastic PS").

**Checkpoint/restore walkthrough** (``--chaos``): run this example with
``--chaos`` to watch the full fault-tolerance stack survive a
*correlated* failure — the one replica promotion cannot absorb.  The
demo trains the CTR model over the elastic fleet with unified
checkpoints (PS slabs + optimizer state + tower params + data cursor,
published atomically behind a ``LATEST`` pointer) every 5 steps, while
a seeded fault schedule crashes **both** replicas of every bucket
inside one step.  The trainer restores the newest checkpoint, rewinds
the deterministic click stream to its cursor, replays, and finishes
with losses bit-equal to a calm run — verified in-process at the end.
The same machinery is exposed on the launcher::

  PYTHONPATH=src python -m repro.launch.train --sparse-ps \
      --steps 60 --ps-shards 3 --ps-optimizer adagrad \
      --ckpt-dir /tmp/ctr-ckpt --ckpt-every 10 \
      --ps-fault 'crash,op=grad,shard=0,after=400,times=1;'\
  'crash,op=grad,shard=1,after=400,times=1'

(see DESIGN.md, "Fault tolerance", for the failure-modes table).
"""

import argparse
import itertools
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TrainingJob, default_fleet, paper_model_profiles
from repro.core.schedulers import RLScheduler
from repro.data import AccessMonitor, PrefetchLoader
from repro.parallel.pipeline import (
    make_stage_mesh, pipeline_loss, stack_stage_params,
)
from repro.ps import (
    CTRConfig, PSClient, PSTelemetry, ShardedTable, TierPlacer, click_stream,
)

VOCAB = 2_000_000
EMB_DIM = 32
SLOTS = 26            # criteo-style sparse slots
TOWER_D = 256
N_STAGES = 4
LAYERS_PER_STAGE = 2
MICRO = 8
MB = 32               # examples per microbatch
PS_SHARDS = 4
REPIN_EVERY = 50

#: the shared synthetic click log (zipf-ish ids, planted logistic
#: structure) at this example's pipeline batch geometry
STREAM_CFG = CTRConfig(vocab=VOCAB, emb_dim=EMB_DIM, slots=SLOTS,
                       batch=MICRO * MB, seed=0)


def chaos_demo(steps: int) -> None:
    """Kill both replicas mid-run; restore the unified checkpoint and
    replay to the calm run's exact loss trajectory (DESIGN.md, "Fault
    tolerance")."""
    import tempfile

    from repro.ps.workload import train_ctr_elastic

    cfg = CTRConfig(vocab=50_000, emb_dim=16, slots=SLOTS, batch=128,
                    seed=0)
    kw = dict(steps=steps, num_shards=3, optimizer="adagrad", mode="sync")
    print(f"calm run: {steps} steps, 3 shards, PS-hosted adagrad")
    calm = train_ctr_elastic(cfg, **kw)
    sched = ("crash,op=grad,shard=0,after=400,times=1;"
             "crash,op=grad,shard=1,after=400,times=1")
    with tempfile.TemporaryDirectory(prefix="ctr-chaos-ckpt-") as d:
        print("chaos run: checkpoint every 5 steps, then crash both "
              "replicas of every bucket inside one step")
        r = train_ctr_elastic(cfg, **kw, ckpt_dir=d, ckpt_every=5,
                              fault_schedule=sched, fault_seed=0)
    for e in r["events"]:
        if e["kind"] in ("detected", "restore"):
            print(f"  event: {e}")
    drift = max(abs(a - b) for a, b in zip(calm["losses"], r["losses"]))
    print(f"crashes injected: "
          f"{sum(i['kind'] == 'crash' for i in r['injections'])}, "
          f"restores: {r['restores']}, checkpoints: "
          f"{[s for s, _ in r['checkpoints']]}")
    print(f"max |loss drift| vs calm run: {drift:.2e} "
          f"({'bit-exact' if drift == 0.0 else 'DRIFTED'})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--chaos", action="store_true",
                    help="run the kill-both-replicas checkpoint/restore "
                         "walkthrough instead of the pipeline")
    args = ap.parse_args()
    if args.chaos:
        chaos_demo(min(args.steps, 40))
        return

    # --- 1. schedule the CTR model with the RL scheduler ---------------
    fleet = default_fleet()
    job = TrainingJob()
    profiles = paper_model_profiles("CTRDNN", fleet)
    res = RLScheduler(rounds=40, seed=0).schedule(profiles, fleet, job)
    print(f"RL-LSTM plan {''.join(map(str, res.plan.assignment))} "
          f"cost {res.cost:.2f} USD, provisioning k={res.prov.k} "
          f"(embedding stage on {fleet[res.plan.assignment[0]].name})")

    # --- 2. build the model: sharded-PS embedding + pipelined tower ----
    key = jax.random.PRNGKey(0)
    monitor = AccessMonitor(VOCAB)
    table = ShardedTable(VOCAB, EMB_DIM, PS_SHARDS, key, init_scale=0.05,
                         monitor=monitor, telemetry=PSTelemetry(PS_SHARDS))
    placer = TierPlacer(table, monitor, interval=REPIN_EVERY)

    d_in = SLOTS * EMB_DIM
    keys = jax.random.split(key, N_STAGES * LAYERS_PER_STAGE + 3)
    in_proj = jax.random.normal(keys[-2], (d_in, TOWER_D)) * (d_in**-0.5)
    stage_list = []
    ki = 0
    for s in range(N_STAGES):
        layers = []
        for _ in range(LAYERS_PER_STAGE):
            layers.append({
                "w": jax.random.normal(keys[ki], (TOWER_D, TOWER_D))
                * (TOWER_D**-0.5),
                "b": jnp.zeros((TOWER_D,)),
            })
            ki += 1
        stage_list.append({"layers": layers})
    head_w = jax.random.normal(keys[-1], (TOWER_D,)) * TOWER_D**-0.5
    stage_params = stack_stage_params(stage_list)
    mesh = make_stage_mesh(min(N_STAGES, jax.device_count()))
    n_params = VOCAB * EMB_DIM + sum(
        int(np.prod(x.shape))
        for x in jax.tree.leaves((stage_params, head_w, in_proj))
    )
    print(f"model: {n_params/1e6:.1f}M params, {N_STAGES}-stage pipeline "
          f"({mesh.shape['stage']} pipeline devices), {MICRO} microbatches, "
          f"embedding on {PS_SHARDS} PS shards")

    def stage_fn(p, x):
        h = x
        for l in range(LAYERS_PER_STAGE):
            h = h + jnp.tanh(h @ p["layers"][l]["w"] + p["layers"][l]["b"])
        return h

    def bce(logit, y):
        return jnp.mean(jnp.maximum(logit, 0) - logit * y
                        + jnp.log1p(jnp.exp(-jnp.abs(logit))))

    def loss_fn(emb, ip, sp, hw, labels):
        # emb enters as the *pulled* PS activation; its gradient is
        # exactly the per-row push payload
        x = emb.reshape(MICRO, MB, d_in) @ ip               # (M, mb, TOWER_D)

        def head_loss(h, y):
            return bce(h @ hw, y)

        return pipeline_loss(stage_fn, head_loss, sp, x,
                             labels.reshape(MICRO, MB), mesh)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn, argnums=(0, 1, 2, 3)))

    # --- 3. train with prefetch + async sharded-PS pull/push -----------
    loader = PrefetchLoader(
        itertools.islice(click_stream(STREAM_CFG), args.steps), depth=2)
    client = PSClient(table, loader, ids_key="ids", depth=2)
    lr = args.lr
    t0 = time.time()
    first = last = None
    try:
        for step, (b, emb) in enumerate(client):
            labels = jnp.asarray(b["label"])
            loss, (g_emb, g_ip, g_sp, g_hw) = grad_fn(
                emb, in_proj, stage_params, head_w, labels
            )
            # PS push (async): only touched rows move; sparse rows get a
            # higher learning rate (few updates per row)
            client.push(b["ids"], g_emb, lr=10.0 * lr)
            in_proj = in_proj - lr * g_ip
            stage_params = jax.tree.map(lambda p, g: p - lr * g,
                                        stage_params, g_sp)
            head_w = head_w - lr * g_hw
            placer.step(step)
            last = float(loss)
            first = first if first is not None else last
            if step % 50 == 0 or step == args.steps - 1:
                print(f"step {step:4d} logloss {last:.4f} "
                      f"({(time.time()-t0)/(step+1):.3f}s/step)", flush=True)
    finally:
        client.close()
        loader.close()

    stats = monitor.stats()
    print(f"\nlogloss {first:.4f} → {last:.4f} "
          f"({'decreased' if last < first else 'did not decrease'})")
    print(f"tier monitor: {stats['device_rows']} hot rows → HBM, "
          f"{stats['host_rows']} warm → host, {stats['disk_rows']} cold → SSD "
          f"(of {VOCAB:,}; {placer.repins} re-pins)")
    tel = table.telemetry.totals()
    print(f"PS traffic: pulled {tel['pull']['bytes']/1e6:.1f} MB "
          f"@ {tel['pull']['bandwidth']/1e6:.1f} MB/s, pushed "
          f"{tel['push']['bytes']/1e6:.1f} MB "
          f"@ {tel['push']['bandwidth']/1e6:.1f} MB/s "
          f"(hot-tier pull fraction {tel['pull']['hot_fraction']:.0%})")
    for r in table.telemetry.shard_report():
        print(f"  shard {r['shard']}: pull {r['pull_rows']} rows "
              f"{r['pull_bytes']/1e6:.1f} MB, push {r['push_rows']} rows "
              f"{r['push_bytes']/1e6:.1f} MB")


if __name__ == "__main__":
    main()
