"""End-to-end HeterPS driver: CTR model with the full distributed stack.

This is the paper's own workload (§6): a CTR model with a huge sparse
embedding (PS-style sparse pull/push) feeding a dense tower, trained on
a streaming synthetic click log with:

* RL-LSTM scheduling of the layer→resource-type plan (and the plan's
  stage partition driving the pipeline split),
* parameter-server sparse embedding updates (only touched rows move),
* GPipe-style pipeline parallelism over the dense-tower stages
  (shard_map + ppermute; with one CPU device the stage mesh is 1-wide —
  run with XLA_FLAGS=--xla_force_host_platform_device_count=4 to see the
  real 4-stage pipeline),
* the data-management access monitor deciding hot/warm/cold row tiers,
* prefetching input pipeline.

Trains ~65M parameters for a few hundred steps; logloss decreases.

Run:  PYTHONPATH=src python examples/heterps_ctr_pipeline.py [--steps 300]
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TrainingJob, default_fleet, paper_model_profiles
from repro.core.schedulers import RLScheduler
from repro.data import AccessMonitor, PrefetchLoader
from repro.parallel.pipeline import (
    make_stage_mesh, pipeline_loss, stack_stage_params,
)
from repro.parallel.ps import sparse_pull

VOCAB = 2_000_000
EMB_DIM = 32
SLOTS = 26            # criteo-style sparse slots
TOWER_D = 256
N_STAGES = 4
LAYERS_PER_STAGE = 2
MICRO = 8
MB = 32               # examples per microbatch


def click_stream(seed: int):
    """Synthetic CTR log: sparse ids + a planted logistic structure."""
    rng = np.random.default_rng(seed)
    w_true = rng.standard_normal(SLOTS) * 0.7
    step = 0
    while True:
        # zipf-ish ids: hot head, long tail (drives the tier monitor)
        ids = (rng.pareto(1.2, (MICRO * MB, SLOTS)) * 1000).astype(np.int64) % VOCAB
        sig = (np.sin(ids % 97) * w_true).sum(-1)
        y = (sig + rng.standard_normal(MICRO * MB) * 0.5 > 0).astype(np.float32)
        yield {"ids": ids.astype(np.int32), "label": y}
        step += 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--lr", type=float, default=0.05)
    args = ap.parse_args()

    # --- 1. schedule the CTR model with the RL scheduler ---------------
    fleet = default_fleet()
    job = TrainingJob()
    profiles = paper_model_profiles("CTRDNN", fleet)
    res = RLScheduler(rounds=40, seed=0).schedule(profiles, fleet, job)
    print(f"RL-LSTM plan {''.join(map(str, res.plan.assignment))} "
          f"cost {res.cost:.2f} USD, provisioning k={res.prov.k} "
          f"(embedding stage on {fleet[res.plan.assignment[0]].name})")

    # --- 2. build the model: PS embedding + pipelined dense tower ------
    key = jax.random.PRNGKey(0)
    table = jax.random.normal(key, (VOCAB, EMB_DIM)) * 0.05
    monitor = AccessMonitor(VOCAB)

    d_in = SLOTS * EMB_DIM
    keys = jax.random.split(key, N_STAGES * LAYERS_PER_STAGE + 3)
    in_proj = jax.random.normal(keys[-2], (d_in, TOWER_D)) * (d_in**-0.5)
    stage_list = []
    ki = 0
    for s in range(N_STAGES):
        layers = []
        for _ in range(LAYERS_PER_STAGE):
            layers.append({
                "w": jax.random.normal(keys[ki], (TOWER_D, TOWER_D))
                * (TOWER_D**-0.5),
                "b": jnp.zeros((TOWER_D,)),
            })
            ki += 1
        stage_list.append({"layers": layers})
    head_w = jax.random.normal(keys[-1], (TOWER_D,)) * TOWER_D**-0.5
    stage_params = stack_stage_params(stage_list)
    mesh = make_stage_mesh(min(N_STAGES, jax.device_count()))
    n_params = VOCAB * EMB_DIM + sum(
        int(np.prod(x.shape))
        for x in jax.tree.leaves((stage_params, head_w, in_proj))
    )
    print(f"model: {n_params/1e6:.1f}M params, {N_STAGES}-stage pipeline "
          f"({mesh.shape['stage']} pipeline devices), {MICRO} microbatches")

    def stage_fn(p, x):
        h = x
        for l in range(LAYERS_PER_STAGE):
            h = h + jnp.tanh(h @ p["layers"][l]["w"] + p["layers"][l]["b"])
        return h

    def bce(logit, y):
        return jnp.mean(jnp.maximum(logit, 0) - logit * y
                        + jnp.log1p(jnp.exp(-jnp.abs(logit))))

    def loss_fn(table, ip, sp, hw, ids, labels):
        emb = sparse_pull(table, ids)                       # PS pull
        x = emb.reshape(MICRO, MB, d_in) @ ip               # (M, mb, TOWER_D)

        def head_loss(h, y):
            return bce(h @ hw, y)

        return pipeline_loss(stage_fn, head_loss, sp, x,
                             labels.reshape(MICRO, MB), mesh)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn, argnums=(0, 1, 2, 3)))

    # --- 3. train with prefetch + sparse PS push ------------------------
    loader = PrefetchLoader(click_stream(0), depth=2)
    lr = args.lr
    t0 = time.time()
    first = last = None
    for step in range(args.steps):
        b = next(loader)
        monitor.record(b["ids"])
        ids = jnp.asarray(b["ids"])
        labels = jnp.asarray(b["label"])
        loss, (g_table, g_ip, g_sp, g_hw) = grad_fn(
            table, in_proj, stage_params, head_w, ids, labels
        )
        # PS push: g_table is a scatter-add of touched rows only; sparse
        # rows get a higher learning rate (few updates per row)
        table = table - 10.0 * lr * g_table
        in_proj = in_proj - lr * g_ip
        stage_params = jax.tree.map(lambda p, g: p - lr * g, stage_params, g_sp)
        head_w = head_w - lr * g_hw
        last = float(loss)
        first = first if first is not None else last
        if step % 50 == 0 or step == args.steps - 1:
            print(f"step {step:4d} logloss {last:.4f} "
                  f"({(time.time()-t0)/(step+1):.3f}s/step)", flush=True)
    loader.close()

    stats = monitor.stats()
    print(f"\nlogloss {first:.4f} → {last:.4f} "
          f"({'decreased' if last < first else 'did not decrease'})")
    print(f"tier monitor: {stats['device_rows']} hot rows → HBM, "
          f"{stats['host_rows']} warm → host, {stats['disk_rows']} cold → SSD "
          f"(of {VOCAB:,})")


if __name__ == "__main__":
    main()
