"""Batched serving example: batched prefill + fused KV-cache decode.

Exercises the three cache families — full attention KV (llama3.2-1b),
sliding-window ring buffer (gemma2-2b), recurrent state (rwkv6-7b,
jamba-v0.1-52b) — then the paged KV cache and the continuous-batching
loop (admit/evict against the shared page pool) on llama3.2-1b.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import sys

sys.path.insert(0, "src")

from repro.launch.serve import serve, serve_continuous


def main() -> None:
    for arch in ("llama3.2-1b", "gemma2-2b", "rwkv6-7b", "jamba-v0.1-52b"):
        out = serve(arch, reduced=True, batch=4, prompt_len=16, gen=16)
        print(f"{arch:20s} gen={out['generated_shape']} "
              f"vocab-valid={out['tokens_in_vocab']} "
              f"decode {out['decode_tok_per_s']:7.1f} tok/s")

    out = serve("llama3.2-1b", reduced=True, batch=4, prompt_len=16, gen=16,
                kv_impl="paged", page_size=8)
    print(f"{'llama3.2-1b/paged':20s} gen={out['generated_shape']} "
          f"decode {out['decode_tok_per_s']:7.1f} tok/s "
          f"kv {out['kv_bytes_per_token']:.0f} B/tok")

    out = serve_continuous("llama3.2-1b", slots=4, page_size=8,
                           decode_chunk=4)
    ratio = out["kv_bytes_per_token_paged"] / out["kv_bytes_per_token_dense"]
    print(f"{'continuous batching':20s} requests={out['requests']} "
          f"gen={out['generated']} decode {out['decode_tok_per_s']:5.1f} "
          f"tok/s kv-bytes ratio paged/dense={ratio:.3f} "
          f"pool-conserved={out['pool_conserved']}")


if __name__ == "__main__":
    main()
