"""Batched serving example: prefill + KV-cache decode on assigned archs.

Exercises the three cache families: full attention KV (llama3.2-1b),
sliding-window ring buffer (gemma2-2b), and recurrent state (rwkv6-7b) —
the long-context decode story of DESIGN.md.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import sys

sys.path.insert(0, "src")

from repro.launch.serve import serve


def main() -> None:
    for arch in ("llama3.2-1b", "gemma2-2b", "rwkv6-7b", "jamba-v0.1-52b"):
        out = serve(arch, reduced=True, batch=4, prompt_len=16, gen=16)
        print(f"{arch:20s} gen={out['generated_shape']} "
              f"vocab-valid={out['tokens_in_vocab']} "
              f"decode {out['decode_tok_per_s']:7.1f} tok/s")


if __name__ == "__main__":
    main()
