"""Quickstart: the full HeterPS flow on the paper's CTRDNN model.

1. Profile the model's layers (OCT/ODT per resource type).
2. Schedule layers to resource types with the RL-LSTM scheduler
   (REINFORCE, Algorithm 1) and compare with baselines.
3. Provision replica counts per stage (load balancing + Newton, §5.1).
4. Report throughput / monetary cost from the cost model (§4.1).
5. Train a reduced assigned architecture end-to-end for a few steps.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

from repro.core import (
    SchedulingPlan, TrainingJob, build_stages, default_fleet,
    paper_model_profiles, pipeline_throughput, plan_cost,
)
from repro.core.schedulers import (
    BruteForceScheduler, GreedyScheduler, HeuristicScheduler, RLScheduler,
)


def main() -> None:
    fleet = default_fleet()
    job = TrainingJob()
    profiles = paper_model_profiles("CTRDNN", fleet)
    print(f"CTRDNN: {len(profiles)} layers; fleet: "
          f"{[r.name for r in fleet]}; throughput limit "
          f"{job.throughput_limit:,.0f} ex/s\n")

    print(f"{'method':12s} {'cost(USD)':>12s} {'time(s)':>9s}  plan")
    results = {}
    for sched in (RLScheduler(rounds=60, seed=0), GreedyScheduler(),
                  HeuristicScheduler()):
        r = sched.schedule(profiles, fleet, job)
        results[sched.name] = r
        print(f"{sched.name:12s} {r.cost:12.3f} {r.wall_time_s:9.2f}  "
              f"{''.join(str(a) for a in r.plan.assignment)}")

    best = results["RL-LSTM"]
    stages = build_stages(best.plan, profiles, fleet)
    print(f"\nRL-LSTM plan → {len(stages)} stages; provisioning "
          f"k={best.prov.k} (+{best.prov.ps_cores} PS cores)")
    tp = pipeline_throughput(stages, best.prov, job.batch_size)
    print(f"estimated throughput {tp:,.0f} ex/s "
          f"(limit {job.throughput_limit:,.0f}) — constraint "
          f"{'satisfied' if tp >= job.throughput_limit else 'VIOLATED'}")

    print("\n--- training a reduced assigned arch for 20 steps ---")
    from repro.launch.train import train

    summary = train("llama3.2-1b", reduced=True, steps=20, batch=8, seq=64,
                    log_every=5)
    print(f"loss {summary['first_loss']:.3f} → {summary['last_loss']:.3f} "
          f"({'decreased' if summary['loss_decreased'] else 'did not decrease'})")


if __name__ == "__main__":
    main()
