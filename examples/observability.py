"""Observability example: traces + metrics from train and serve runs.

Shows the `--obs-dir` workflow as a library user sees it:

1. enable obs and run a short sparse-PS training job over the
   *multiprocess* transport — the spawned shard workers inherit the obs
   switch via ``REPRO_OBS`` and ship their spans back, so the merged
   ``trace.json`` has one lane per worker pid next to the main process;
2. run a continuous-batching serve with open-loop arrivals and read the
   TTFT/TPOT histograms back from the metric registry;
3. feed the live metrics through the cost-model bridge
   (``obs.snapshot_resources``) to get the ``ResourceType`` shape the
   scheduler consumes.

The same outputs come from the CLIs:

  PYTHONPATH=src python -m repro.launch.train --sparse-ps --steps 20 \\
      --ps-shards 2 --ps-transport multiproc --obs-dir /tmp/obsrun
  PYTHONPATH=src python -m repro.launch.serve --continuous \\
      --obs-dir /tmp/obsrun
  PYTHONPATH=src python benchmarks/bench_slo.py --smoke --obs-dir /tmp/obsrun

Open ``<obs-dir>/trace.json`` at https://ui.perfetto.dev (or
``chrome://tracing``); each ``metrics.jsonl`` line is one JSON snapshot.

Run:  PYTHONPATH=src python examples/observability.py
"""

import json
import sys
import tempfile

sys.path.insert(0, "src")

from repro import obs
from repro.core.resources import CPU_CORE
from repro.launch.serve import serve_continuous
from repro.launch.train import train_sparse_ps


def main() -> None:
    run_dir = tempfile.mkdtemp(prefix="obsrun-")
    obs.configure(run_dir=run_dir)   # implies enabled=True; sets REPRO_OBS

    # 1) multiproc PS training: worker spans merge in as their own pid lanes
    summary = train_sparse_ps(steps=20, num_shards=2, transport="multiproc",
                              log_every=0)
    print(f"train: {summary['steps_per_sec']:.1f} steps/s, "
          f"pull {summary['pull_bw_gbs']:.2f} GB/s")

    # 2) continuous serve with open-loop arrivals → TTFT/TPOT histograms
    reqs = [(8, 4), (8, 8), (16, 4), (8, 4)]
    out = serve_continuous("llama3.2-1b", slots=2, page_size=8,
                           decode_chunk=4, requests=reqs,
                           arrival_s=[0.0, 0.05, 0.1, 0.4])
    ttft = obs.REGISTRY.find("serve.ttft_s")[0][1]
    print(f"serve: {out['decode_tok_per_s']:.1f} tok/s, "
          f"ttft p50={ttft.quantile(0.5):.3f}s p99={ttft.quantile(0.99):.3f}s")

    # 3) live cost-model bridge: measured PS bandwidths + serve signals in
    # the exact shapes core/profiles.py consumes
    snap = obs.snapshot_resources(CPU_CORE)
    print(f"bridge: {snap['resource'].name} "
          f"ingest_bw={snap['resource'].ingest_bw / 1e9:.2f} GB/s "
          f"net_bw={snap['resource'].net_bw / 1e9:.2f} GB/s")

    paths = obs.flush()
    trace = json.load(open(paths["trace"]))
    pids = {e["pid"] for e in trace["traceEvents"]}
    print(f"wrote {paths['trace']} ({len(trace['traceEvents'])} events, "
          f"{len(pids)} process lanes) and {paths['metrics']}")


if __name__ == "__main__":
    main()
