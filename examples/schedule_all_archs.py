"""Schedule the 10 assigned architectures with HeterPS (RL-LSTM vs
baselines) — the paper's technique applied beyond its own CTR models.

Each arch's layers are profiled analytically (FLOPs/bytes per layer →
OCT/ODT on each resource type) and scheduled to a heterogeneous fleet.

Run:  PYTHONPATH=src python examples/schedule_all_archs.py
"""

import sys

sys.path.insert(0, "src")

from repro.configs import ARCH_IDS
from repro.core import TrainingJob, make_fleet
from repro.core.schedulers import GreedyScheduler, HeuristicScheduler, RLScheduler
from repro.models.profile import profile_arch


def main() -> None:
    fleet = make_fleet(4)
    job = TrainingJob(batch_size=256, throughput_limit=2_000.0,
                      num_examples=50_000_000)
    print(f"fleet: {[r.name for r in fleet]}\n")
    print(f"{'arch':26s} {'RL-LSTM':>10s} {'Greedy':>10s} {'Heuristic':>10s}  stages")
    for arch in ARCH_IDS:
        profiles = profile_arch(arch, fleet)
        rl = RLScheduler(rounds=40, seed=0).schedule(profiles, fleet, job)
        gr = GreedyScheduler().schedule(profiles, fleet, job)
        he = HeuristicScheduler().schedule(profiles, fleet, job)
        n_stages = len(rl.plan.stage_boundaries())
        print(f"{arch:26s} {rl.cost:10.2f} {gr.cost:10.2f} {he.cost:10.2f}  "
              f"{n_stages}")


if __name__ == "__main__":
    main()
